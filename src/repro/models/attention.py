"""GQA attention: qk-norm, qkv-bias, RoPE, KV cache, optional flash kernel.

The pure-jnp path is the default (and the one the dry-run lowers, so
``cost_analysis`` sees real einsum FLOPs). The Pallas flash kernel in
``repro.kernels`` is opt-in via ``use_flash=True`` for TPU runs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm
from repro.models.pdefs import ParamDef
from repro.sharding.rules import shard

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


def attn_defs(cfg, std=0.02):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, H, hd), ("hidden", "heads", "head_dim"), std=std),
        "wk": ParamDef((d, KV, hd), ("hidden", "kv_heads", "kv_head_dim"), std=std),
        "wv": ParamDef((d, KV, hd), ("hidden", "kv_heads", "kv_head_dim"), std=std),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "hidden"), std=std),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((KV, hd), ("kv_heads", "kv_head_dim"), init="zeros")
        defs["bv"] = ParamDef((KV, hd), ("kv_heads", "kv_head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="zeros")
        defs["k_norm"] = ParamDef((hd,), (None,), init="zeros")
    return defs


def _project_qkv(p, cfg, x, rope_sc):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope_sc is not None:
        sin, cos = rope_sc
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q:[B,Sq,H,hd] k,v:[B,Sk,KV,hd]; GQA by head-group reshape. fp32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q, k, v, causal, scale, block_q=512):
    """Query-blocked exact attention: scores materialize per q-block only.

    Pure-XLA flash-style scan (so dry-run cost_analysis sees the real dot
    FLOPs); the Pallas kernel is the TPU-optimized twin of this."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    if Sq % bq:  # non-power-of-two seq (e.g. whisper's 1500 frames)
        for cand in range(min(block_q, Sq), 0, -1):
            if Sq % cand == 0:
                bq = cand
                break
    nb = Sq // bq
    qb = q.reshape(B, nb, bq, H, hd).swapaxes(0, 1)  # [nb,B,bq,H,hd]

    def body(_, args):
        i, qi = args
        if causal:
            qpos = i * bq + jnp.arange(bq)
            mask = (qpos[:, None] >= jnp.arange(Sk)[None, :])[None, None, None]
        else:
            mask = None
        out = _sdpa(qi, k, v, mask, scale)
        return None, out

    body = jax.checkpoint(body, prevent_cse=False)
    _, ob = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    return ob.swapaxes(0, 1).reshape(B, Sq, H, hd)


def attn_apply(p, cfg, x, rope_sc, causal=True, use_flash=False):
    """Full-sequence attention (train / prefill)."""
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    q, k, v = _project_qkv(p, cfg, x, rope_sc)
    # NOTE: activations keep head_dim unsharded even when the weights use
    # the head_dim fallback (non-divisible heads): contracting a sharded
    # hd in the score einsum would all-reduce [B,*,S,block] fp32 tensors
    # every block; gathering the (small) qkv weights instead is ~free.
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal)
    elif x.shape[1] > 1024:
        out = _sdpa_chunked(q, k, v, causal, scale)
    else:
        mask = None
        if causal:
            S = x.shape[1]
            mask = jnp.tril(jnp.ones((S, S), bool))[None, None, None, :, :]
        out = _sdpa(q, k, v, mask, scale)
    out = shard(out, "batch", "seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def attn_decode(p, cfg, x, rope_sc, cache_k, cache_v, pos):
    """Single-token decode. x:[B,1,d]; cache:[B,S,KV,hd]; pos:[] int32."""
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    q, k, v = _project_qkv(p, cfg, x, rope_sc)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    S = cache_k.shape[1]
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, valid, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (cache_k, cache_v)


def cross_attn_apply(p, cfg, x, kv_cache):
    """Cross attention against precomputed (k, v) from the encoder."""
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    k, v = kv_cache
    out = _sdpa(q, k, v, None, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_kv(p, cfg, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v
