"""Single-source-of-truth parameter definitions.

Each parameter is declared once as a :class:`ParamDef` carrying its shape,
*logical* axis names, and init recipe. From a (nested) tree of ParamDefs we
derive: concrete init, ShapeDtypeStruct stand-ins (dry-run), and
PartitionSpecs (via the sharding rules resolver).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"                 # normal | zeros | ones
    std: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _map(defs, fn):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_def)


def init_tree(key, defs, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        elif d.init == "hippo":
            # S4D-real init: A_log[..., n] = log(n+1), broadcast over leading dims
            n = d.shape[-1]
            row = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            out.append(jnp.broadcast_to(row, d.shape).astype(dtype))
        else:
            out.append((jax.random.normal(k, d.shape, jnp.float32) * d.std).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(defs, dtype=jnp.float32):
    return _map(defs, lambda d: jax.ShapeDtypeStruct(d.shape, dtype))


def pspec_tree(defs, resolve):
    """resolve(logical_name, dim_size) -> mesh axis (or None)."""
    def one(d: ParamDef):
        axes = []
        used = set()
        for name, size in zip(d.logical, d.shape):
            ax = resolve(name, size) if name else None
            # a mesh axis may appear at most once per spec
            if ax is not None and (ax in used or (isinstance(ax, tuple) and any(a in used for a in ax))):
                ax = None
            if ax is not None:
                used.update(ax if isinstance(ax, tuple) else (ax,))
            axes.append(ax)
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)
    return _map(defs, one)


def stack_defs(defs, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dim (for scan-over-layers) to every ParamDef."""
    return _map(defs, lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.logical, d.init, d.std))


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
