"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a stub: ``input_specs`` provides precomputed
frame embeddings [B, n_frames, d]. Encoder = bidirectional self-attn
stack; decoder = causal self-attn + cross-attn. Learned positions sized
to the shape cell. Output projection tied to the decoder embedding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models.pdefs import ParamDef, stack_defs
from repro.sharding.rules import shard


def _enc_layer_defs(cfg):
    return {
        "ln1": ParamDef((cfg.d_model,), ("hidden",), init="zeros"),
        "attn": attn.attn_defs(cfg),
        "ln2": ParamDef((cfg.d_model,), ("hidden",), init="zeros"),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act),
    }


def _dec_layer_defs(cfg):
    d = _enc_layer_defs(cfg)
    d["ln_x"] = ParamDef((cfg.d_model,), ("hidden",), init="zeros")
    d["xattn"] = attn.attn_defs(cfg)
    return d


def encdec_defs(cfg, s_max: int, std=0.02):
    return {
        "enc": {
            "pos": ParamDef((cfg.n_frames, cfg.d_model), (None, "hidden"), std=std),
            "blocks": stack_defs(_enc_layer_defs(cfg), cfg.n_enc_layers),
            "final_norm": ParamDef((cfg.d_model,), ("hidden",), init="zeros"),
        },
        "dec": {
            "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "hidden"), std=std),
            "pos": ParamDef((s_max, cfg.d_model), (None, "hidden"), std=std),
            "blocks": stack_defs(_dec_layer_defs(cfg), cfg.n_layers),
            "final_norm": ParamDef((cfg.d_model,), ("hidden",), init="zeros"),
        },
    }


def encode(params, cfg, frames, use_flash=False):
    x = frames + params["enc"]["pos"].astype(frames.dtype)
    def body(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = attn.attn_apply(p["attn"], cfg, h, None, causal=False, use_flash=use_flash)
        x = x + y
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg.act)
        return shard(x, "batch", "seq_res", "hidden"), None
    x, _ = jax.lax.scan(body, x, params["enc"]["blocks"])
    return L.rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def _dec_body(cfg, use_flash, mode):
    def seq_body(x, xs):
        p, kvx = xs
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, (k, v) = attn.attn_apply(p["attn"], cfg, h, None, causal=True, use_flash=use_flash)
        x = x + y
        h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attn_apply(p["xattn"], cfg, h, kvx)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg.act)
        x = shard(x, "batch", "seq_res", "hidden")
        if mode == "prefill":
            return x, (k, v)
        return x, None
    return seq_body


def decode_train(params, cfg, tokens, enc_out, use_flash=False, remat=True):
    """Teacher-forced decoder pass. Returns hidden [B,S,d]."""
    S = tokens.shape[1]
    x = L.embed_apply(params["dec"]["embed"], tokens)
    x = x + params["dec"]["pos"][:S].astype(x.dtype)
    cross = _cross_caches(params, cfg, enc_out)
    body = _dec_body(cfg, use_flash, "train")
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["dec"]["blocks"], cross))
    return L.rms_norm(x, params["dec"]["final_norm"], cfg.norm_eps)


def _cross_caches(params, cfg, enc_out):
    def body(_, p):
        return None, attn.cross_kv(p["xattn"], cfg, enc_out)
    _, cross = jax.lax.scan(body, None, params["dec"]["blocks"])
    return cross


def decode_prefill(params, cfg, tokens, enc_out, cache_dtype=jnp.bfloat16, use_flash=False):
    """Returns (hidden, cache) where cache = {self_k, self_v, cross_k, cross_v}."""
    S = tokens.shape[1]
    x = L.embed_apply(params["dec"]["embed"], tokens)
    x = x + params["dec"]["pos"][:S].astype(x.dtype)
    cross = _cross_caches(params, cfg, enc_out)
    body = _dec_body(cfg, use_flash, "prefill")
    x, selfkv = jax.lax.scan(body, x, (params["dec"]["blocks"], cross))
    cache = {"self_k": selfkv[0].astype(cache_dtype), "self_v": selfkv[1].astype(cache_dtype),
             "cross_k": cross[0].astype(cache_dtype), "cross_v": cross[1].astype(cache_dtype)}
    return L.rms_norm(x, params["dec"]["final_norm"], cfg.norm_eps), cache


def decode_step(params, cfg, token, pos, cache):
    """token: [B,1]; pos scalar. Returns (hidden, new_cache)."""
    x = L.embed_apply(params["dec"]["embed"], token)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec"]["pos"], pos, 1, axis=0).astype(x.dtype)

    def body(x, xs):
        p, (sk, sv, xk, xv) = xs
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        y, (nk, nv) = attn.attn_decode(p["attn"], cfg, h, None, sk, sv, pos)
        x = x + y
        h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attn_apply(p["xattn"], cfg, h, (xk, xv))
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg.act)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"]["blocks"],
                  (cache["self_k"], cache["self_v"], cache["cross_k"], cache["cross_v"])))
    new_cache = dict(cache, self_k=nk, self_v=nv)
    return L.rms_norm(x, params["dec"]["final_norm"], cfg.norm_eps), new_cache


def encdec_cache_specs(cfg, batch, s_max, dtype=jnp.bfloat16):
    KV, hd, Ld = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_layers
    F = cfg.n_frames
    return {
        "self_k": jax.ShapeDtypeStruct((Ld, batch, s_max, KV, hd), dtype),
        "self_v": jax.ShapeDtypeStruct((Ld, batch, s_max, KV, hd), dtype),
        "cross_k": jax.ShapeDtypeStruct((Ld, batch, F, KV, hd), dtype),
        "cross_v": jax.ShapeDtypeStruct((Ld, batch, F, KV, hd), dtype),
    }


def logits(params, cfg, x):
    out = jnp.einsum("bsd,vd->bsv", x, params["dec"]["embed"])
    return shard(out, "batch", "seq", "vocab")
