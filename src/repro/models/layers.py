"""Shared layer primitives: norms, RoPE, activations, GLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.pdefs import ParamDef
from repro.sharding.rules import shard


def rms_norm(x, weight, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------- RoPE ----------------

def rope_tables(positions, head_dim, theta):
    """positions: int32 [...]. Returns (sin, cos) of shape [..., head_dim//2], fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: [B, S, H, D]; sin/cos: [B, S, D//2] or [S, D//2]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # [S, half] -> broadcast over batch and heads
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:              # [B, S, half]
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(dt)


# ---------------- activations ----------------

def activation(name, x, gate=None):
    if name == "swiglu":
        return jax.nn.silu(gate) * x
    if name == "geglu":
        return jax.nn.gelu(gate) * x
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------- GLU MLP ----------------

def mlp_defs(d_model, d_ff, act, std=0.02):
    gated = act in ("swiglu", "geglu")
    defs = {
        "up": ParamDef((d_model, d_ff), ("hidden", "ffn"), std=std),
        "down": ParamDef((d_ff, d_model), ("ffn", "hidden"), std=std),
    }
    if gated:
        defs["gate"] = ParamDef((d_model, d_ff), ("hidden", "ffn"), std=std)
    return defs


def mlp_apply(p, x, act):
    b, s, _ = x.shape
    h = jnp.einsum("bsd,df->bsf", x, p["up"])
    if "gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["gate"])
        h = activation(act, h, g)
    else:
        h = activation(act, h)
    h = shard(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["down"])


# ---------------- embeddings ----------------

def embed_defs(vocab, d_model, std=0.02):
    return ParamDef((vocab, d_model), ("vocab", "hidden"), std=std)


def embed_apply(table, tokens, scale=None):
    y = jnp.take(table, tokens, axis=0)
    if scale is not None:
        y = y * scale
    return y
