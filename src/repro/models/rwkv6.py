"""RWKV-6 (Finch) mixer: data-dependent decay linear attention.

Time-mix uses the DDLerp token-shift (low-rank modulated mixes for
r/k/v/w/g), a per-channel data-dependent decay w_t = exp(-exp(w0 +
lora(x))) and the bonus term u. Full-sequence processing is *chunked*:
within a chunk the decay products are formed in log space (all exponents
<= 0, so no overflow) and contracted as [T, T, head_dim] fp32 blocks;
across chunks a lax.scan carries the [B, H, hd, hd] wkv state. Decode is
the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation
from repro.models.pdefs import ParamDef
from repro.sharding.rules import shard

TM_LORA = 32
DECAY_LORA = 64


def rwkv_defs(cfg, std=0.02):
    d = cfg.d_model
    H = cfg.n_heads
    hd = cfg.resolved_head_dim
    f = cfg.d_ff
    return {
        "tm": {
            "mix_base": ParamDef((5, d), (None, "hidden"), init="zeros"),
            "mix_lora_a": ParamDef((d, 5 * TM_LORA), ("hidden", None), std=std),
            "mix_lora_b": ParamDef((5, TM_LORA, d), (None, None, "hidden"), std=std),
            "wr": ParamDef((d, H, hd), ("hidden", "heads", None), std=std),
            "wk": ParamDef((d, H, hd), ("hidden", "heads", None), std=std),
            "wv": ParamDef((d, H, hd), ("hidden", "heads", None), std=std),
            "wg": ParamDef((d, H, hd), ("hidden", "heads", None), std=std),
            "wo": ParamDef((H, hd, d), ("heads", None, "hidden"), std=std),
            "w0": ParamDef((H, hd), ("heads", None), init="zeros"),
            "decay_a": ParamDef((d, DECAY_LORA), ("hidden", None), std=std),
            "decay_b": ParamDef((DECAY_LORA, H, hd), (None, "heads", None), std=std),
            "u": ParamDef((H, hd), ("heads", None), init="zeros"),
            "ln_w": ParamDef((H, hd), ("heads", None), init="ones"),
            "ln_b": ParamDef((H, hd), ("heads", None), init="zeros"),
        },
        "cm": {
            "mix_k": ParamDef((d,), ("hidden",), init="zeros"),
            "mix_r": ParamDef((d,), ("hidden",), init="zeros"),
            "wk": ParamDef((d, f), ("hidden", "ffn"), std=std),
            "wv": ParamDef((f, d), ("ffn", "hidden"), std=std),
            "wr": ParamDef((d, d), ("hidden", "hidden_tp"), std=std),
        },
    }


def _token_shift(x, last_x):
    """x:[B,S,d]; last_x:[B,d] (previous token across call boundary)."""
    prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def _ddlerp(p, x, prev):
    """Returns the five DDLerp-mixed streams [5][B,S,d]: r,k,v,w,g order."""
    dx = prev - x
    xxx = x + dx * p["mix_base"].sum(0) * 0.0  # base offset folded into per-stream below
    lo = jnp.tanh(jnp.einsum("bsd,dk->bsk", x + dx * 0.5, p["mix_lora_a"]))
    lo = lo.reshape(*lo.shape[:-1], 5, TM_LORA)
    mod = jnp.einsum("bsik,ikd->bsid", lo, p["mix_lora_b"])    # [B,S,5,d]
    mixes = x[:, :, None, :] + dx[:, :, None, :] * (p["mix_base"][None, None] + mod)
    del xxx
    return [mixes[:, :, i, :] for i in range(5)]


def _decay(p, mix_w):
    """w in (0,1): [B,S,H,hd] fp32 log-decay (<=0)."""
    lo = jnp.tanh(jnp.einsum("bsd,dk->bsk", mix_w, p["decay_a"]))
    dw = jnp.einsum("bsk,khd->bshd", lo, p["decay_b"])
    logw = -jnp.exp((p["w0"][None, None] + dw).astype(jnp.float32) - 0.5)
    return logw  # log(w_t) = -exp(...) <= 0


def _wkv_chunk(r, k, v, logw, u, S0):
    """One chunk, fp32. r,k,v,logw: [B,H,T,hd]; u:[H,hd]; S0:[B,H,hd,hd].

    y_t = r_t S_{t-1} + (r_t*u*k_t)·v_t ; S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    cum = jnp.cumsum(logw, axis=2)                             # [B,H,T,hd]
    cum_prev = cum - logw                                      # cum_{t-1}
    # inter-chunk: a_t = r_t * exp(cum_{t-1})  (exponent <= 0)
    a = r * jnp.exp(cum_prev)
    y_inter = jnp.einsum("bhtc,bhcv->bhtv", a, S0)
    # intra-chunk: Q[t,s] = sum_c r_t[c] k_s[c] exp(cum_{t-1}[c] - cum_s[c]), s < t
    T = r.shape[2]
    D = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,H,T,S,hd]
    mask = (jnp.arange(T)[:, None] > jnp.arange(T)[None, :])[None, None, :, :, None]
    W = jnp.exp(jnp.where(mask, D, -jnp.inf))
    Q = jnp.einsum("bhtc,bhsc,bhtsc->bhts", r, k, W)
    bonus = jnp.einsum("bhtc,bhtc->bht", r * u[None, :, None, :], k)
    Q = Q + jnp.eye(T)[None, None] * bonus[:, :, :, None]
    y_intra = jnp.einsum("bhts,bhsv->bhtv", Q, v)
    # state update: S_T = exp(cum_T) S_0 + sum_s (k_s exp(cum_T - cum_s))^T v_s
    decay_total = jnp.exp(cum[:, :, -1])                       # [B,H,hd]
    kd = k * jnp.exp(cum[:, :, -1:, :] - cum)
    S_new = decay_total[..., None] * S0 + jnp.einsum("bhtc,bhtv->bhcv", kd, v)
    return y_inter + y_intra, S_new


def _group_norm(x, w, b, eps=64e-5):
    """x:[B,S,H,hd]; per-head layer norm (rwkv ln_x)."""
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def time_mix_seq(p, cfg, x, state):
    """x:[B,S,d]; state {'last_x':[B,d], 'wkv':[B,H,hd,hd] fp32}."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    prev, new_last = _token_shift(x, state["last_x"])
    mr, mk, mv, mw, mg = _ddlerp(p, x, prev)
    r = jnp.einsum("bsd,dhk->bhsk", mr, p["wr"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bhsk", mk, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bhsk", mv, p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", mg, p["wg"]))
    logw = _decay(p, mw).swapaxes(1, 2)                        # [B,H,S,hd]

    T = min(cfg.ssm.chunk if cfg.ssm else 128, S)
    while S % T:  # non-divisible seq: largest divisor <= chunk
        T -= 1
    nc = S // T
    def split(z):
        return z.reshape(B, H, nc, T, hd).swapaxes(0, 2).swapaxes(1, 2)  # [nc,B,H,T,hd]
    rc, kc, vc, wc = split(r), split(k), split(v), split(logw)

    u = p["u"].astype(jnp.float32)
    def body(S0, xs):
        rt, kt, vt, wt = xs
        y, S1 = _wkv_chunk(rt, kt, vt, wt, u, S0)
        return S1, y
    # nested remat: the [B,H,T,T,hd] decay blocks never outlive a chunk
    S_new, yc = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                             state["wkv"], (rc, kc, vc, wc))
    y = yc.swapaxes(1, 2).swapaxes(0, 2).reshape(B, H, S, hd).swapaxes(1, 2)  # [B,S,H,hd]
    y = _group_norm(y.astype(jnp.float32), p["ln_w"], p["ln_b"]).astype(x.dtype)
    y = y * g
    y = shard(y, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return out, {"last_x": new_last, "wkv": S_new}


def time_mix_decode(p, cfg, x, state):
    """x:[B,1,d]."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    prev = state["last_x"][:, None, :]
    mr, mk, mv, mw, mg = _ddlerp(p, x, prev)
    r = jnp.einsum("bsd,dhk->bhk", mr[:, 0:1], p["wr"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bhk", mk[:, 0:1], p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bhk", mv[:, 0:1], p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bhk", mg[:, 0:1], p["wg"]))
    w = jnp.exp(_decay(p, mw)[:, 0])                           # [B,H,hd]
    u = p["u"].astype(jnp.float32)
    S0 = state["wkv"]
    kv = k[..., :, None] * v[..., None, :]                     # [B,H,hd,hd]
    y = jnp.einsum("bhc,bhcv->bhv", r, S0) + jnp.einsum("bhc,bhcv->bhv", r * u[None], kv)
    S1 = w[..., :, None] * S0 + kv
    y = _group_norm(y[:, None, :, :], p["ln_w"], p["ln_b"])[:, 0].astype(x.dtype)
    y = (y * g).reshape(B, 1, H * hd).reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return out, {"last_x": x[:, 0, :], "wkv": S1}


def channel_mix(p, cfg, x, state):
    """x:[B,S,d]; state {'last_x':[B,d]}. Works for S==1 (decode) too."""
    prev, new_last = _token_shift(x, state["last_x"])
    dx = prev - x
    xk = x + dx * p["mix_k"]
    xr = x + dx * p["mix_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = activation("relu_sq", k)
    k = shard(k, "batch", "seq", "ffn")
    v = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return r * v, {"last_x": new_last}


def rwkv_state_defs(cfg, batch, dtype=jnp.float32):
    H, hd, d = cfg.n_heads, cfg.resolved_head_dim, cfg.d_model
    return {
        "tm": {"last_x": jax.ShapeDtypeStruct((batch, d), dtype),
               "wkv": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32)},
        "cm": {"last_x": jax.ShapeDtypeStruct((batch, d), dtype)},
    }
