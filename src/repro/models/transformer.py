"""Unified decoder stack: scan-over-layers with heterogeneous block patterns.

A *pattern* of period P describes each layer position's (mixer, mlp) pair —
dense archs have P=1 (attn+dense), jamba has P=8 (7 mamba + 1 attn, MoE on
odd positions), rwkv has P=1 (time-mix + channel-mix). Parameters are
stacked over n_layers // P groups and the stack is a single ``lax.scan``,
which keeps the lowered HLO small enough to compile 512-device meshes
quickly.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.pdefs import ParamDef, stack_defs
from repro.sharding.rules import shard


# ---------------- pattern ----------------

def layer_pattern(cfg) -> Tuple[Tuple[str, str], ...]:
    moe_every = cfg.moe.every if cfg.moe else 1
    P = 1
    for k in (cfg.attn_every, moe_every):
        P = P * k // math.gcd(P, k)
    out = []
    for p in range(P):
        if cfg.attn_free:
            mixer = "rwkv"
        elif cfg.ssm is not None and cfg.attn_every > 1:
            mixer = "attn" if p % cfg.attn_every == cfg.attn_every // 2 else "mamba"
        else:
            mixer = "attn"
        if cfg.attn_free:
            mlp = "rwkv_cm"
        elif cfg.moe and p % moe_every == moe_every - 1:
            mlp = "moe"
        else:
            mlp = "dense"
        out.append((mixer, mlp))
    assert cfg.n_layers % P == 0, (cfg.n_layers, P)
    return tuple(out)


def n_groups(cfg) -> int:
    return cfg.n_layers // len(layer_pattern(cfg))


# ---------------- parameter definitions ----------------

def _pos_defs(cfg, mixer, mlp):
    d = cfg.d_model
    defs = {"ln1": ParamDef((d,), ("hidden",), init="zeros"),
            "ln2": ParamDef((d,), ("hidden",), init="zeros")}
    if mixer == "attn":
        defs["mixer"] = attn.attn_defs(cfg)
    elif mixer == "mamba":
        defs["mixer"] = mb.mamba_defs(cfg)
    elif mixer == "rwkv":
        rdefs = rk.rwkv_defs(cfg)
        defs["mixer"] = rdefs["tm"]
        defs["cm"] = rdefs["cm"]
    if mlp == "dense":
        defs["mlp"] = L.mlp_defs(d, cfg.d_ff, cfg.act)
    elif mlp == "moe":
        defs["mlp"] = moe_mod.moe_defs(cfg)
    return defs


def lm_defs(cfg, std=0.02):
    pat = cfg and layer_pattern(cfg)
    G = n_groups(cfg)
    blocks = {f"p{i}": stack_defs(_pos_defs(cfg, mx, ml), G)
              for i, (mx, ml) in enumerate(pat)}
    defs = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "hidden"), std=std),
        "final_norm": ParamDef((cfg.d_model,), ("hidden",), init="zeros"),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.padded_vocab), ("hidden", "vocab"), std=std)
    return defs


# ---------------- caches ----------------

def cache_specs(cfg, batch: int, s_max: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree mirroring the decode cache (per pattern position)."""
    G = n_groups(cfg)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def stackg(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((G,) + s.shape, s.dtype), tree)

    out = {}
    for i, (mx, ml) in enumerate(layer_pattern(cfg)):
        c = {}
        if mx == "attn":
            c["k"] = jax.ShapeDtypeStruct((batch, s_max, KV, hd), dtype)
            c["v"] = jax.ShapeDtypeStruct((batch, s_max, KV, hd), dtype)
        elif mx == "mamba":
            c.update(mb.mamba_state_defs(cfg, batch, dtype))
        elif mx == "rwkv":
            r = rk.rwkv_state_defs(cfg, batch, dtype)
            c["tm"] = r["tm"]
            c["cm"] = r["cm"]
        out[f"p{i}"] = stackg(c)
    return out


def init_cache(cfg, batch, s_max, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  cache_specs(cfg, batch, s_max, dtype))


def cache_pspecs(cfg, batch, s_max, rules):
    """PartitionSpecs for the cache: kv-heads on model, long seq on data (SP)."""
    from jax.sharding import PartitionSpec as P

    def one(path_sds):
        sds = path_sds
        shp = sds.shape
        if len(shp) == 5:  # (G, B, S, KV, hd) attention cache
            kv_ax = rules.resolve("kv_heads", shp[3])
            hd_ax = rules.resolve("kv_head_dim", shp[4])  # model iff kv failed
            b_ax = rules.resolve("batch", shp[1])
            s_ax = None
            if b_ax is None or (shp[1] % max(rules._axis_size(b_ax), 1)) != 0:
                b_ax = None
            if shp[1] == 1:  # long-context single-request: shard sequence (SP)
                b_ax = None
                s_ax = rules.resolve("seq_sp", shp[2])
            return P(None, b_ax, s_ax, kv_ax, hd_ax)
        # states: shard batch dim (axis 1) when divisible
        if len(shp) >= 2:
            b_ax = rules.resolve("batch", shp[1])
            return P(None, b_ax, *([None] * (len(shp) - 2)))
        return P()
    return jax.tree_util.tree_map(one, cache_specs(cfg, batch, s_max))


# ---------------- forward ----------------

def _rope_sc(cfg, positions):
    if cfg.rope_theta <= 0:
        return None
    return L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)


def _block_seq(cfg, pat, params_g, x, rope_sc, cache_g, mode, use_flash):
    """Apply one pattern group (P sub-layers) over a full sequence.

    cache_g: per-position cache slice (no G axis) or None (train).
    Returns (x, new_cache_g, aux)."""
    aux = {"moe_aux": 0.0, "moe_z": 0.0}
    new_cache = {}
    # multi-sublayer groups (jamba P=8) remat each sublayer too, so the
    # group's backward holds one sublayer's recompute at a time
    inner_ckpt = mode == "train" and len(pat) > 1

    def one(x, p, mx, ml):
        nc = {}
        a = {"moe_aux": 0.0, "moe_z": 0.0}
        # NOTE(perf log): a "gather the residual once per sublayer" variant
        # (tag spv2) was measured and REVERTED: XLA already CSEs the twin
        # SP gathers, so it only cut collectives 11% while materializing
        # replicated residuals (+14 GiB temp). See EXPERIMENTS.md §Perf.
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mx == "attn":
            y, (k, v) = attn.attn_apply(p["mixer"], cfg, h, rope_sc,
                                        causal=True, use_flash=use_flash)
            if mode == "prefill":
                nc["k"], nc["v"] = k, v
        elif mx == "mamba":
            y, st = mb.mamba_seq(p["mixer"], cfg, h)
            if mode == "prefill":
                nc.update(st)
        else:  # rwkv
            B = x.shape[0]
            zeros = {"last_x": jnp.zeros((B, cfg.d_model), x.dtype),
                     "wkv": jnp.zeros((B, cfg.n_heads, cfg.resolved_head_dim,
                                       cfg.resolved_head_dim), jnp.float32)}
            y, st = rk.time_mix_seq(p["mixer"], cfg, h, zeros)
            if mode == "prefill":
                nc["tm"] = st
        x = x + y
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ml == "dense":
            y = L.mlp_apply(p["mlp"], h, cfg.act)
        elif ml == "moe":
            y, a2 = moe_mod.moe_apply(p["mlp"], cfg, h)
            a = {k2: a[k2] + a2[k2] for k2 in a}
        else:  # rwkv channel mix
            B = x.shape[0]
            y, st = rk.channel_mix(p["cm"], cfg, h,
                                   {"last_x": jnp.zeros((B, cfg.d_model), x.dtype)})
            if mode == "prefill":
                nc["cm"] = st
        x = x + y
        x = shard(x, "batch", "seq_res", "hidden")
        return x, nc, a

    for i, (mx, ml) in enumerate(pat):
        p = params_g[f"p{i}"]
        c = cache_g[f"p{i}"] if cache_g is not None else None
        fn = one
        if inner_ckpt:
            fn = jax.checkpoint(lambda x, p, mx=mx, ml=ml: one(x, p, mx, ml),
                                prevent_cse=False, static_argnums=())
            x, nc, a = fn(x, p)
        else:
            x, nc, a = one(x, p, mx, ml)
        aux = {k2: aux[k2] + a[k2] for k2 in aux}
        if mode == "prefill" and c is not None:
            nc = jax.tree_util.tree_map(lambda t, n: n.astype(t.dtype), c, nc)
        new_cache[f"p{i}"] = nc
    return x, (new_cache if mode == "prefill" else None), aux


def _block_decode(cfg, pat, params_g, x, rope_sc, cache_g, pos):
    """One pattern group, single-token decode. Returns (x, new_cache_g)."""
    new_cache = {}
    for i, (mx, ml) in enumerate(pat):
        p = params_g[f"p{i}"]
        c = cache_g[f"p{i}"]
        nc = {}
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mx == "attn":
            y, (k, v) = attn.attn_decode(p["mixer"], cfg, h, rope_sc, c["k"], c["v"], pos)
            nc["k"], nc["v"] = k, v
        elif mx == "mamba":
            y, st = mb.mamba_decode(p["mixer"], cfg, h, c)
            nc.update(st)
        else:  # rwkv
            y, st = rk.time_mix_decode(p["mixer"], cfg, h, c["tm"])
            nc["tm"] = st
        x = x + y
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ml == "dense":
            y = L.mlp_apply(p["mlp"], h, cfg.act)
        elif ml == "moe":
            y, _ = moe_mod.moe_apply(p["mlp"], cfg, h)
        else:
            y, st = rk.channel_mix(p["cm"], cfg, h, c["cm"])
            nc["cm"] = st
        x = x + y
        new_cache[f"p{i}"] = nc
    return x, new_cache


def forward_train(params, cfg, x, positions, remat=True, use_flash=False):
    """x: [B,S,d] embedded input. Returns (hidden, aux)."""
    pat = layer_pattern(cfg)
    rope_sc = _rope_sc(cfg, positions)

    def body(carry, params_g):
        x, am, az = carry
        x, _, aux = _block_seq(cfg, pat, params_g, x, rope_sc, None, "train", use_flash)
        return (x, am + aux["moe_aux"], az + aux["moe_z"]), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=True)
    (x, am, az), _ = jax.lax.scan(body, (x, 0.0, 0.0), params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, {"moe_aux": am, "moe_z": az}


def forward_prefill(params, cfg, x, positions, s_max, cache_dtype=jnp.bfloat16,
                    use_flash=False):
    """Returns (hidden, cache). Prompt length must equal s_max for attn cache."""
    pat = layer_pattern(cfg)
    rope_sc = _rope_sc(cfg, positions)
    G = n_groups(cfg)
    cache_tmpl = init_cache(cfg, x.shape[0], s_max, cache_dtype)

    def body(x, xs):
        params_g, cache_g = xs
        x, nc, _ = _block_seq(cfg, pat, params_g, x, rope_sc, cache_g, "prefill", use_flash)
        # conform returned states to the cache template dtypes
        merged = jax.tree_util.tree_map(lambda t, n: n.astype(t.dtype), cache_g, nc)
        return x, merged

    x, cache = jax.lax.scan(body, x, (params["blocks"], cache_tmpl))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, cache


def forward_decode(params, cfg, x, pos, cache):
    """x: [B,1,d]; pos: scalar int32. Returns (hidden, new_cache).

    The cache rides the scan *carry* (updated in place per group) rather
    than xs/ys, so XLA keeps a single buffer instead of input+output
    copies — at 32k-context decode that halves cache residency."""
    pat = layer_pattern(cfg)
    rope_sc = _rope_sc(cfg, pos[None]) if cfg.rope_theta > 0 else None

    def body(carry, params_g):
        x, cache, g = carry
        cache_g = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, g, 0, keepdims=False), cache)
        x, nc = _block_decode(cfg, pat, params_g, x, rope_sc, cache_g, pos)
        cache = jax.tree_util.tree_map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), g, 0), cache, nc)
        return (x, cache, g + 1), None

    (x, new_cache, _), _ = jax.lax.scan(
        body, (x, cache, jnp.int32(0)), params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache


def logits_from_hidden(params, cfg, x):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    eq = "bsd,vd->bsv" if cfg.tie_embeddings else "bsd,dv->bsv"
    logits = jnp.einsum(eq, x, table)
    return shard(logits, "batch", "seq", "vocab")


def embed_tokens(params, cfg, tokens):
    x = L.embed_apply(params["embed"], tokens)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x
