"""Top-k MoE with capacity-based one-hot dispatch (GSPMD/MaxText style).

Experts shard over the "model" mesh axis (expert parallelism). Tokens are
grouped along the batch dim; the dispatch/combine tensors are built as
products of an expert one-hot and a slot one-hot so XLA keeps everything
as sharded einsums (all-to-all emerges from the resharding between the
token-sharded and expert-sharded operands).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation
from repro.models.pdefs import ParamDef
from repro.sharding.rules import shard


def moe_defs(cfg, std=0.02):
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_ff
    defs = {
        "router": ParamDef((d, E), ("hidden", "experts"), std=std),
        "up": ParamDef((E, d, f), ("experts", "hidden", "ffn"), std=std),
        "down": ParamDef((E, f, d), ("experts", "ffn", "hidden"), std=std),
    }
    if cfg.act in ("swiglu", "geglu"):
        defs["gate"] = ParamDef((E, d, f), ("experts", "hidden", "ffn"), std=std)
    return defs


def capacity(tokens_per_group: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(math.ceil(tokens_per_group * top_k * cf / n_experts))
    return max(c, 1)


def moe_apply(p, cfg, x) -> Tuple[jnp.ndarray, dict]:
    """x: [B, S, d] -> (y, aux) where aux carries load-balance/z losses.

    Tokens regroup into dispatch groups of ``group_size`` so the dispatch
    tensor is O(tokens * group_size * top_k * cf) — independent of E."""
    m = cfg.moe
    B0, S0, d = x.shape
    M = min(m.group_size, S0)
    while S0 % M:
        M -= 1
    x = x.reshape(B0 * (S0 // M), M, d)
    B, S, _ = x.shape
    E, K = m.n_experts, m.top_k
    C = capacity(S, E, K, m.capacity_factor)

    # --- routing (fp32) ---
    logits = jnp.einsum("gsd,de->gse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # [G,S,K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=(0, 1))                          # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=2), axis=(0, 1))
    aux_loss = E * jnp.sum(me * ce) * m.aux_coef
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef

    # --- capacity assignment ---
    oh_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # [G,S,K,E]
    # position of each (token, k) within its expert queue, priority by (s, k)
    pos = jnp.cumsum(oh_e.reshape(B, S * K, E), axis=1).reshape(B, S, K, E) * oh_e - 1
    slot = jnp.sum(pos * oh_e, axis=-1)                        # [G,S,K]
    keep = (slot >= 0) & (slot < C)
    oh_c = jax.nn.one_hot(slot, C, dtype=x.dtype) * keep[..., None].astype(x.dtype)

    oh_e_f = oh_e.astype(x.dtype)
    # dispatch/combine: [G,S,E,C]
    dispatch = jnp.einsum("gske,gskc->gsec", oh_e_f, oh_c)
    combine = jnp.einsum("gske,gskc,gsk->gsec", oh_e_f, oh_c, gate_vals.astype(x.dtype))

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, x)
    xe = shard(xe, "batch", "experts", None, "hidden")
    h = jnp.einsum("gecd,edf->gecf", xe, p["up"])
    if "gate" in p:
        g = jnp.einsum("gecd,edf->gecf", xe, p["gate"])
        h = activation(cfg.act, h, g)
    else:
        h = activation(cfg.act, h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"])
    ye = shard(ye, "batch", "experts", None, "hidden")
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)
    return y.reshape(B0, S0, d), {"moe_aux": aux_loss, "moe_z": z_loss}
