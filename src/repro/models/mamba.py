"""Mamba (selective SSM) mixer with chunked selective scan.

Full-sequence processing scans over chunks (``cfg.ssm.chunk`` tokens) and
uses an associative scan *within* each chunk, so the materialized
discretized-state tensor is only [B, chunk, d_inner, d_state] — this is
what makes train_4k and long-context shapes fit HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.pdefs import ParamDef
from repro.sharding.rules import shard


def mamba_defs(cfg, std=0.02):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    R = cfg.dt_rank
    N = s.d_state
    return {
        "in_proj": ParamDef((d, 2 * di), ("hidden", "ffn"), std=std),
        "conv_w": ParamDef((s.d_conv, di), (None, "ffn"), std=std),
        "conv_b": ParamDef((di,), ("ffn",), init="zeros"),
        "x_proj": ParamDef((di, R + 2 * N), ("ffn", None), std=std),
        "dt_w": ParamDef((R, di), (None, "ffn"), std=std),
        "dt_b": ParamDef((di,), ("ffn",), init="zeros"),
        "A_log": ParamDef((di, N), ("ffn", "d_state"), init="hippo"),
        "D": ParamDef((di,), ("ffn",), init="ones"),
        "out_proj": ParamDef((di, d), ("ffn", "hidden"), std=std),
    }


def _causal_conv(u, w, b, init_state=None):
    """u:[B,S,di]; w:[K,di] depthwise causal. init_state:[B,K-1,di] or None."""
    K = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([init_state, u], axis=1)
    y = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    new_state = up[:, up.shape[1] - (K - 1):, :]
    return y + b, new_state


def _ssm_params(p, cfg, u):
    """u:[B,T,di] (post conv+silu) -> dt:[B,T,di], Bm/Cm:[B,T,N] (fp32)."""
    s = cfg.ssm
    R = cfg.dt_rank
    N = s.d_state
    xdbc = jnp.einsum("btd,dk->btk", u, p["x_proj"]).astype(jnp.float32)
    dt_lo, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("btr,rd->btd", dt_lo, p["dt_w"].astype(jnp.float32))
                         + p["dt_b"].astype(jnp.float32) - 4.0)
    return dt, Bm, Cm


def _chunk_scan(dA, dBu, h0):
    """dA,dBu:[B,T,di,N] fp32; h0:[B,di,N]. Returns hs:[B,T,di,N], hT."""
    def comb(a, b):
        return (a[0] * b[0], b[1] + b[0] * a[1])
    a_cum, b_cum = jax.lax.associative_scan(comb, (dA, dBu), axis=1)
    hs = b_cum + a_cum * h0[:, None]
    return hs, hs[:, -1]


def mamba_seq(p, cfg, x, state=None):
    """Full-sequence mamba. x:[B,S,d]. Returns (y, new_state)."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.expand * d
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shard(xz, "batch", "seq", "ffn")
    u, z = jnp.split(xz, 2, axis=-1)
    z = shard(z, "batch", "seq", "ffn")
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u)
    u = shard(u, "batch", "seq", "ffn")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [di,N]
    h0 = jnp.zeros((B, di, s.d_state), jnp.float32) if state is None else state["h"]

    T = min(s.chunk, S)
    while S % T:  # non-divisible seq: largest divisor <= chunk
        T -= 1
    nc = S // T
    uc = u.reshape(B, nc, T, di).swapaxes(0, 1)                # [nc,B,T,di]

    def body(h, u_t):
        u_t = shard(u_t, "batch", None, "ffn")
        dt, Bm, Cm = _ssm_params(p, cfg, u_t)
        dt = shard(dt, "batch", None, "ffn")
        dA = jnp.exp(dt[..., None] * A)                        # [B,T,di,N]
        dBu = (dt * u_t.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
        dA = shard(dA, "batch", None, "ffn", None)
        dBu = shard(dBu, "batch", None, "ffn", None)
        hs, hT = _chunk_scan(dA, dBu, h)
        y = jnp.einsum("btdn,btn->btd", hs, Cm)
        y = y + u_t.astype(jnp.float32) * p["D"].astype(jnp.float32)
        return shard(hT, "batch", "ffn", None), y.astype(x.dtype)

    # nested remat: group-level backward recomputes chunk internals one
    # chunk at a time instead of holding [B,S,di,N]-scale tensors live
    hT, yc = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), h0, uc)
    y = yc.swapaxes(0, 1).reshape(B, S, di)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "h": hT}


def mamba_decode(p, cfg, x, state):
    """Single-token decode. x:[B,1,d]."""
    s = cfg.ssm
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    u = jax.nn.silu(u)
    dt, Bm, Cm = _ssm_params(p, cfg, u)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :, None] * A)                        # [B,di,N]
    dBu = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = dA * state["h"] + dBu
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + u[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x.dtype)[:, None, :] * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "h": h}


def mamba_state_defs(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), dtype),
        "h": jax.ShapeDtypeStruct((batch, di, s.d_state), jnp.float32),
    }
