"""Model zoo: build per-(arch, shape) functional models.

``build(cfg, s_max)`` returns a :class:`Model` whose pure functions are
what the launchers jit/lower: ``loss_fn`` (train), ``prefill_fn``,
``decode_fn`` (serve). Inputs for the dry-run come from
``input_specs(shape)`` as ShapeDtypeStructs (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import encdec as ed
from repro.models import pdefs
from repro.models import transformer as tf
from repro.sharding.rules import Rules, shard


def _ce_loss(cfg, logits, targets, mask=None):
    """fp32 CE with padded-vocab masking + z-loss."""
    logits = logits.astype(jnp.float32)
    pad_bias = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, 0.0, -1e9)
    logits = logits + pad_bias
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    z = 1e-4 * lse ** 2
    per_tok = nll + z
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_tok * mask).sum() / denom


def _block_len(S, target=512, align=16):
    """Largest block <= target dividing S, preferring SP-friendly multiples."""
    for bs in range(min(target, S), 0, -1):
        if S % bs == 0 and bs % align == 0:
            return bs
    for bs in range(min(target, S), 0, -1):
        if S % bs == 0:
            return bs
    return S


def _ce_loss_chunked(cfg, head_fn, h, targets, block=512):
    """Chunked CE: logits are materialized one seq-block at a time and
    recomputed in the backward pass (the full [B,S,V] fp32 logits tensor
    never exists)."""
    B, S, _ = h.shape
    bs = _block_len(S, block)
    nb = S // bs
    hb = h.reshape(B, nb, bs, -1).swapaxes(0, 1)
    tb = targets.reshape(B, nb, bs).swapaxes(0, 1)
    # keep each chunk sequence-sharded: without this the reshape forces a
    # full fp32 all-gather of the hidden states (and replicated dW chunks)
    hb = shard(hb, None, "batch", "seq_res", "hidden")

    def body(acc, xs):
        hi, ti = xs
        loss = _ce_loss(cfg, head_fn(hi), ti)
        return acc + loss, None

    body = jax.checkpoint(body, prevent_cse=False)
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hb, tb))
    return tot / nb


@dataclasses.dataclass
class Model:
    cfg: Any
    s_max: int
    defs: Any
    loss_fn: Callable            # (params, batch) -> (loss, metrics)
    prefill_fn: Optional[Callable]   # (params, batch) -> (last_logits, cache)
    decode_fn: Optional[Callable]    # (params, cache, token, pos) -> (logits, cache)
    cache_specs: Optional[Callable]  # (batch_size) -> SDS tree
    cache_pspecs: Optional[Callable] # (batch_size, rules) -> pspec tree

    def init(self, key, dtype=jnp.float32):
        return pdefs.init_tree(key, self.defs, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return pdefs.abstract_tree(self.defs, dtype)

    def param_pspecs(self, rules: Rules):
        return pdefs.pspec_tree(self.defs, rules.resolve)

    def n_params(self) -> int:
        return pdefs.count_params(self.defs)

    # ---- input specs for the dry-run ----
    def input_specs(self, shape) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        d = cfg.d_model
        if shape.kind == "train":
            out = {"tokens": jax.ShapeDtypeStruct((B, self._tok_len(S)), jnp.int32),
                   "targets": jax.ShapeDtypeStruct((B, self._tok_len(S)), jnp.int32)}
        elif shape.kind == "prefill":
            out = {"tokens": jax.ShapeDtypeStruct((B, self._tok_len(S)), jnp.int32)}
        else:  # decode
            out = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                   "pos": jax.ShapeDtypeStruct((), jnp.int32),
                   "cache": self.cache_specs(B)}
        if cfg.family == "vlm" and shape.kind != "decode":
            out["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, d), jnp.bfloat16)
        if cfg.family == "encdec" and shape.kind != "decode":
            out["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, d), jnp.bfloat16)
        return out

    def input_pspecs(self, shape, rules: Rules) -> Dict[str, Any]:
        cfg = self.cfg
        B = shape.global_batch
        bax = rules.resolve("batch", B)
        out: Dict[str, Any] = {}
        if shape.kind == "train":
            out = {"tokens": P(bax, None), "targets": P(bax, None)}
        elif shape.kind == "prefill":
            out = {"tokens": P(bax, None)}
        else:
            out = {"token": P(bax, None), "pos": P(),
                   "cache": self.cache_pspecs(B, rules)}
        if cfg.family == "vlm" and shape.kind != "decode":
            out["patches"] = P(bax, None, None)
        if cfg.family == "encdec" and shape.kind != "decode":
            out["frames"] = P(bax, None, None)
        return out

    def _tok_len(self, S):
        # VLM cells: patch prefix + tokens = S total positions
        if self.cfg.family == "vlm":
            return S - self.cfg.n_patches
        return S


# ---------------- decoder-only LM (dense/moe/hybrid/ssm/vlm) ----------------

def _build_lm(cfg, s_max, use_flash=False, remat=True, cache_dtype=jnp.bfloat16):
    defs = tf.lm_defs(cfg)

    def embed_inputs(params, batch, S_tok):
        x = tf.embed_tokens(params, cfg, batch["tokens"])
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        return shard(x, "batch", "seq", "hidden")

    def loss_fn(params, batch):
        S_tok = batch["tokens"].shape[1]
        x = embed_inputs(params, batch, S_tok)
        positions = jnp.arange(x.shape[1])
        h, aux = tf.forward_train(params, cfg, x, positions, remat=remat,
                                  use_flash=use_flash)
        if cfg.family == "vlm":  # loss only on token region
            h = h[:, cfg.n_patches:, :]
        head = lambda hi: tf.logits_from_hidden(params, cfg, hi)
        ce = _ce_loss_chunked(cfg, head, h, batch["targets"])
        loss = ce + aux["moe_aux"] + aux["moe_z"]
        return loss, {"ce": ce, "moe_aux": aux["moe_aux"], "moe_z": aux["moe_z"]}

    def prefill_fn(params, batch):
        x = embed_inputs(params, batch, batch["tokens"].shape[1])
        positions = jnp.arange(x.shape[1])
        h, cache = tf.forward_prefill(params, cfg, x, positions, s_max=x.shape[1],
                                      use_flash=use_flash)
        logits = tf.logits_from_hidden(params, cfg, h[:, -1:, :])
        return logits, cache

    def decode_fn(params, cache, token, pos):
        x = tf.embed_tokens(params, cfg, token)
        h, cache = tf.forward_decode(params, cfg, x, pos, cache)
        logits = tf.logits_from_hidden(params, cfg, h)
        return logits, cache

    return Model(
        cfg=cfg, s_max=s_max, defs=defs,
        loss_fn=loss_fn, prefill_fn=prefill_fn, decode_fn=decode_fn,
        cache_specs=lambda B: tf.cache_specs(cfg, B, s_max, cache_dtype),
        cache_pspecs=lambda B, rules: tf.cache_pspecs(cfg, B, s_max, rules),
    )


# ---------------- encoder-decoder (whisper) ----------------

def _build_encdec(cfg, s_max, use_flash=False, remat=True, cache_dtype=jnp.bfloat16):
    defs = ed.encdec_defs(cfg, s_max)

    def loss_fn(params, batch):
        enc_out = ed.encode(params, cfg, batch["frames"], use_flash)
        h = ed.decode_train(params, cfg, batch["tokens"], enc_out, use_flash, remat)
        head = lambda hi: ed.logits(params, cfg, hi)
        loss = _ce_loss_chunked(cfg, head, h, batch["targets"])
        return loss, {"ce": loss}

    def prefill_fn(params, batch):
        enc_out = ed.encode(params, cfg, batch["frames"], use_flash)
        h, cache = ed.decode_prefill(params, cfg, batch["tokens"], enc_out)
        logits = ed.logits(params, cfg, h[:, -1:, :])
        return logits, cache

    def decode_fn(params, cache, token, pos):
        h, cache = ed.decode_step(params, cfg, token, pos, cache)
        logits = ed.logits(params, cfg, h)
        return logits, cache

    def cache_pspecs(B, rules):
        bax = rules.resolve("batch", B)
        kv = rules.resolve("kv_heads", cfg.n_kv_heads)
        hd = rules.resolve("kv_head_dim", cfg.resolved_head_dim)
        spec = P(None, bax, None, kv, hd)
        return {k: spec for k in ("self_k", "self_v", "cross_k", "cross_v")}

    return Model(
        cfg=cfg, s_max=s_max, defs=defs,
        loss_fn=loss_fn, prefill_fn=prefill_fn, decode_fn=decode_fn,
        cache_specs=lambda B: ed.encdec_cache_specs(cfg, B, s_max, cache_dtype),
        cache_pspecs=cache_pspecs,
    )


def build(cfg, s_max: int, use_flash: bool = False, remat: bool = True,
          cache_dtype=jnp.bfloat16) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg, s_max, use_flash, remat, cache_dtype)
    return _build_lm(cfg, s_max, use_flash, remat, cache_dtype)
