"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bloom import bloom_probe_jnp


def flash_attention_ref(q, k, v, causal=True):
    """q: [BHq, Sq, hd]; k, v: [BHkv, Sk, hd] (GQA by ratio)."""
    BH, Sq, hd = q.shape
    BK, Sk, _ = k.shape
    G = BH // BK
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)


def bloom_probe_ref(words, keys, k: int, m_bits: int):
    return bloom_probe_jnp(jnp.asarray(words), m_bits, k,
                           keys).astype(jnp.int8)


def rowclone_copy_ref(x):
    return x
