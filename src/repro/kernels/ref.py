"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bloom import bloom_probe_jnp


def flash_attention_ref(q, k, v, causal=True):
    """q: [BHq, Sq, hd]; k, v: [BHkv, Sk, hd] (GQA by ratio)."""
    BH, Sq, hd = q.shape
    BK, Sk, _ = k.shape
    G = BH // BK
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)


def bloom_probe_ref(words, keys, k: int, m_bits: int):
    return bloom_probe_jnp(jnp.asarray(words), m_bits, k,
                           keys).astype(jnp.int8)


def rowclone_copy_ref(x):
    return x


def policy_vm_ref(tables, envm):
    """Pure-jnp oracle for ``policy_vm_scores``: vmap of the table VM
    over the program axis. tables [P, L+1, 4], envm [N_LOADS, Q] ->
    [P, 3, Q] int32 (score, boost, mitigate)."""
    from repro.core.smcprog import eval_table_rows
    tables = jnp.asarray(tables, jnp.int32)
    envm = jnp.asarray(envm, jnp.int32)

    def one(table):
        hdr = table[0]
        rows = table[1:]
        lb = rows.shape[0]
        vals = eval_table_rows(rows, envm)
        score = vals[jnp.clip(hdr[1], 0, lb - 1)]
        zero = jnp.zeros_like(score)
        boost = jnp.where(hdr[2] >= 0,
                          vals[jnp.clip(hdr[2], 0, lb - 1)], zero)
        mit = jnp.where(hdr[3] >= 0,
                        vals[jnp.clip(hdr[3], 0, lb - 1)], zero)
        return jnp.stack([score, boost, mit])

    return jax.vmap(one)(tables)
