"""Policy-VM batch evaluation as a Pallas kernel (the policy-axis hot
spot: many packed policy tables × one queue-environment matrix).

One grid cell evaluates ONE packed program — the ``[L + 1, 4]`` header +
instruction table pins in VMEM next to the shared ``[N_LOADS, Q]``
environment block, and the cell interprets the table with the exact
:func:`repro.core.smcprog.eval_table_rows` dataflow (imported, not
re-implemented — single source of VM semantics, so kernel == reference
bit-identity is structural, not coincidental). Output per cell is the
``(score, boost, mitigate)`` triple the scheduler's argmin consumes.

On CPU (this container) the kernel runs in interpret mode for
correctness validation; on TPU the same call compiles to Mosaic. The
batched use case is offline policy screening (``core.policysearch``
scoring hundreds of candidate tables against captured queue snapshots)
— inside the emulator's scan the per-decision environment is a single
[Q] vector, far below kernel launch granularity, so the engine keeps
its inline VM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.smcprog import N_LOADS, eval_table_rows


def _kernel(table_ref, env_ref, out_ref):
    table = table_ref[0]                  # [L + 1, 4] int32
    envm = env_ref[...]                   # [N_LOADS, Q] int32
    hdr = table[0]
    rows = table[1:]
    lb = rows.shape[0]
    vals = eval_table_rows(rows, envm)    # [L, Q] int32
    score = vals[jnp.clip(hdr[1], 0, lb - 1)]
    zero = jnp.zeros_like(score)
    boost = jnp.where(hdr[2] >= 0, vals[jnp.clip(hdr[2], 0, lb - 1)], zero)
    mit = jnp.where(hdr[3] >= 0, vals[jnp.clip(hdr[3], 0, lb - 1)], zero)
    out_ref[0] = jnp.stack([score, boost, mit])


@functools.partial(jax.jit, static_argnames=("interpret",))
def policy_vm_scores(tables, envm, interpret=False):
    """tables: [P, L + 1, 4] int32 packed programs
    (:func:`repro.core.smcprog.pack_stack` layout); envm: [N_LOADS, Q]
    int32 shared environment -> [P, 3, Q] int32 (score, boost,
    mitigate) per program."""
    tables = jnp.asarray(tables, jnp.int32)
    envm = jnp.asarray(envm, jnp.int32)
    P, L1, _ = tables.shape
    Q = envm.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, L1, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((N_LOADS, Q), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3, Q), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 3, Q), jnp.int32),
        interpret=interpret,
    )(tables, envm)
