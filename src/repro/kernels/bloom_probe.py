"""Bloom-filter probe as a Pallas kernel (Sec. 8.2's per-ACT hot path).

The bit array (2^20 bits = 128 KiB of u32 words) pins in VMEM; query
blocks of 1024 keys stream through, each hashed k times with the same
mix as ``core.bloom``. Gathers over the VMEM-resident word array are
cheap on TPU; output is one int8 per key (1 = possibly weak -> nominal
tRCD, 0 = definitely strong -> reduced tRCD).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MULS = (0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F, 0x165667B1,
         0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2D)


def _kernel(words_ref, keys_ref, out_ref, *, k, m_bits):
    keys = keys_ref[:].astype(jnp.uint32)
    words = words_ref[:]
    hit = jnp.ones(keys.shape, jnp.bool_)
    for i in range(k):
        x = keys
        x = x ^ (x >> 16)
        x = x * jnp.uint32(_MULS[i])
        x = x ^ (x >> 13)
        x = x * jnp.uint32(0x2B2AE3D5)
        x = x ^ (x >> 16)
        idx = x & jnp.uint32(m_bits - 1)
        w = words[(idx >> 5).astype(jnp.int32)]
        bit = (w >> (idx & 31)) & jnp.uint32(1)
        hit = hit & (bit == 1)
    out_ref[:] = hit.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("k", "m_bits", "block", "interpret"))
def bloom_probe(words, keys, k: int, m_bits: int, block: int = 1024,
                interpret=False):
    """words: [m_bits//32] uint32; keys: [N] uint32 -> int8 [N]."""
    N = keys.shape[0]
    pad = (-N) % block
    keys_p = jnp.pad(keys, (0, pad))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, m_bits=m_bits),
        grid=(keys_p.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((words.shape[0],), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(keys_p.shape, jnp.int8),
        interpret=interpret,
    )(words, keys_p)
    return out[:N]
