"""Bulk page copy as a Pallas kernel — the TPU analogue of RowClone.

In-DRAM copy's insight is "move data without the processor touching it";
the closest TPU-idiomatic equivalent is an HBM->HBM tiled copy that never
enters compute: rows stream through VMEM in (BR, C) tiles, grid over row
blocks. Used by the serve engine's KV-page fork. VREGs stay untouched —
the roofline cost is pure HBM bandwidth, the quantity RowClone attacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rowclone_copy(x, block_rows: int = 8, interpret=False):
    """x: [R, C] -> copy. Tile = (block_rows, C) through VMEM."""
    R, C = x.shape
    br = block_rows
    while R % br:
        br -= 1
    return pl.pallas_call(
        _kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
