"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs as traced Python for correctness validation; on TPU the
same calls compile to Mosaic. ``flash_attention`` takes the model-layout
[B, S, H, hd] tensors and handles the GQA head flattening + the
long-context fallback to the chunked-XLA path.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import bloom_probe as _bp
from repro.kernels import flash_attention as _fa
from repro.kernels import rowclone_copy as _rc

_INTERPRET = jax.default_backend() == "cpu"
_MAX_KV_VMEM = 8192  # Sk beyond this falls back to the chunked XLA path

# REPRO_POLICY_VM_KERNEL: "1" forces the Pallas policy-VM kernel (in
# interpret mode on CPU), "0" forces the pure-jnp reference. Default:
# kernel on accelerators, reference on CPU (interpret-mode tracing is a
# correctness tool, not a fast path).
_POLICY_VM_FLAG = os.environ.get("REPRO_POLICY_VM_KERNEL", "")


def flash_attention(q, k, v, causal=True):
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] -> [B,S,H,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if k.shape[1] > _MAX_KV_VMEM or Sq % 128:
        from repro.models.attention import _sdpa_chunked
        return _sdpa_chunked(q, k, v, causal, hd ** -0.5)
    # GQA layout: group q heads by kv head so kernel i//G indexing works
    G = H // KV
    qr = (q.transpose(0, 2, 1, 3)
          .reshape(B, KV, G, Sq, hd).reshape(B * KV * G, Sq, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], hd)
    o = _fa.flash_attention_bhsd(qr, kr, vr, causal=causal,
                                 interpret=_INTERPRET)
    return (o.reshape(B, KV, G, Sq, hd).reshape(B, H, Sq, hd)
            .transpose(0, 2, 1, 3))


def bloom_probe(words, keys, k: int, m_bits: int):
    return _bp.bloom_probe(jnp.asarray(words), jnp.asarray(keys, jnp.uint32),
                           k=k, m_bits=m_bits, interpret=_INTERPRET)


def rowclone_copy(x):
    return _rc.rowclone_copy(x, interpret=_INTERPRET)


def policy_vm(tables, envm):
    """Batch policy-VM scoring: packed tables [P, L+1, 4] x shared env
    [N_LOADS, Q] -> [P, 3, Q] (score, boost, mitigate). Routes to the
    Pallas kernel or the jnp reference per ``REPRO_POLICY_VM_KERNEL``
    (see module docstring); both are bit-identical by construction —
    they share ``smcprog.eval_table_rows``."""
    use_kernel = (_POLICY_VM_FLAG == "1"
                  or (_POLICY_VM_FLAG != "0" and not _INTERPRET))
    if use_kernel:
        from repro.kernels import policy_vm as _pv
        return _pv.policy_vm_scores(tables, envm, interpret=_INTERPRET)
    from repro.kernels import ref as _ref
    return _ref.policy_vm_ref(tables, envm)
