"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs as traced Python for correctness validation; on TPU the
same calls compile to Mosaic. ``flash_attention`` takes the model-layout
[B, S, H, hd] tensors and handles the GQA head flattening + the
long-context fallback to the chunked-XLA path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import bloom_probe as _bp
from repro.kernels import flash_attention as _fa
from repro.kernels import rowclone_copy as _rc

_INTERPRET = jax.default_backend() == "cpu"
_MAX_KV_VMEM = 8192  # Sk beyond this falls back to the chunked XLA path


def flash_attention(q, k, v, causal=True):
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] -> [B,S,H,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if k.shape[1] > _MAX_KV_VMEM or Sq % 128:
        from repro.models.attention import _sdpa_chunked
        return _sdpa_chunked(q, k, v, causal, hd ** -0.5)
    # GQA layout: group q heads by kv head so kernel i//G indexing works
    G = H // KV
    qr = (q.transpose(0, 2, 1, 3)
          .reshape(B, KV, G, Sq, hd).reshape(B * KV * G, Sq, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], hd)
    o = _fa.flash_attention_bhsd(qr, kr, vr, causal=causal,
                                 interpret=_INTERPRET)
    return (o.reshape(B, KV, G, Sq, hd).reshape(B, H, Sq, hd)
            .transpose(0, 2, 1, 3))


def bloom_probe(words, keys, k: int, m_bits: int):
    return _bp.bloom_probe(jnp.asarray(words), jnp.asarray(keys, jnp.uint32),
                           k=k, m_bits=m_bits, interpret=_INTERPRET)


def rowclone_copy(x):
    return _rc.rowclone_copy(x, interpret=_INTERPRET)
