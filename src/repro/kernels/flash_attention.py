"""Flash attention (GQA, causal) as a Pallas TPU kernel.

Tiling: grid = (B * KV * G, Sq / BQ). Each program holds one q block
[BQ, hd] plus its kv-head's full K/V rows in VMEM and streams kv chunks
of BK with the online-softmax recurrence (fp32 m/l/acc). BQ=BK=128 keeps
the MXU fed (hd is 64/128/256 for the assigned archs). K/V VMEM residency
bounds Sk <= ~8k at hd=128 bf16; the ops wrapper falls back to the
chunked-XLA path beyond that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, bq, bk, q_start_blocks):
    qi = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32) * scale          # [BQ, hd]
    Sk = k_ref.shape[1]
    nk = Sk // bk

    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, q.shape[-1]), jnp.float32)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # [BK, hd]
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                              # [BQ, BK]
        if causal:
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, nk, body, (m, l, acc))
    o_ref[0, :, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, causal=True, bq=128, bk=128, interpret=False):
    """q: [BHq, Sq, hd]; k, v: [BHkv, Sk, hd]; BHq = BHkv * G."""
    BH, Sq, hd = q.shape
    BK = k.shape[0]
    G = BH // BK
    bq = min(bq, Sq)
    bk = min(bk, k.shape[1])
    assert Sq % bq == 0 and k.shape[1] % bk == 0
    scale = hd ** -0.5
    grid = (BH, Sq // bq)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          q_start_blocks=0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k.shape[1], hd), lambda i, j, G=G: (i // G, 0, 0)),
            pl.BlockSpec((1, v.shape[1], hd), lambda i, j, G=G: (i // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
