"""Deterministic data pipeline: synthetic LM batches, host-sharded.

Synthetic sequences are a seeded Markov-ish token stream with enough
structure that cross-entropy visibly falls during the example training
runs. ``ShardedLoader`` yields only this host's slice of the global
batch (data-parallel ingestion); ``skip_to(step)`` gives exact resume
after checkpoint restart.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_patterns: int = 64):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        rng = np.random.RandomState(seed)
        # structured source: each sequence interleaves a repeated motif
        # with noise, so an LM can reach well below uniform entropy
        self.motifs = rng.randint(0, vocab, size=(n_patterns, 8))

    def batch(self, step: int, host_slice: slice = slice(None)):
        rng = np.random.RandomState(self.seed * 100003 + step)
        B, S = self.global_batch, self.seq_len
        m = rng.randint(0, len(self.motifs), size=B)
        toks = np.tile(self.motifs[m], (1, S // 8 + 2))[:, :S + 1]
        noise = rng.randint(0, self.vocab, size=(B, S + 1))
        mask = rng.rand(B, S + 1) < 0.15
        toks = np.where(mask, noise, toks).astype(np.int32)
        toks = toks[host_slice]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}


class ShardedLoader:
    """Iterator over this host's shard of the global batch."""

    def __init__(self, source: SyntheticLM, host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0):
        assert source.global_batch % n_hosts == 0
        per = source.global_batch // n_hosts
        self.slice = slice(host_id * per, (host_id + 1) * per)
        self.source = source
        self.step = start_step

    def skip_to(self, step: int):
        self.step = step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.source.batch(self.step, self.slice)
        self.step += 1
        return b
