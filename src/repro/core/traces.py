"""Workload trace generators for the EasyDRAM engine.

Three families, mirroring the paper's evaluation:
* microbenchmarks — Copy/Init (Sec. 7), lmbench-style pointer-chase
  latency sweep (Fig. 8);
* PolyBench-like kernels (Sec. 6/8) — synthetic address streams with the
  suite's spread of memory intensities, filtered through the LLC model;
* LM step traces — DRAM-level traffic of a train/decode step of the
  assigned architectures (weights + KV-cache streaming), tying the LM
  framework to the memory-system evaluation.

Plus the streaming front door (PR 7): :func:`load_trace_file` parses
ramulator-style / MemTraceProbe-style text traces into the address
stream :func:`dram_trace_from_stream` consumes;
:func:`iter_trace_file_windows` and :func:`iter_windows` yield bounded
:class:`Trace` windows for ``emulator.run_stream`` so production-scale
traces are never materialized whole; :func:`synthetic_stream` generates
an unbounded random request stream window by window for steady-state
throughput measurements.
"""
from __future__ import annotations

import dataclasses
import gzip
from typing import Iterator, Optional

import numpy as np

from repro.core.cachesim import LLC, filter_stream
from repro.core.dram import Geometry, NOP, RC_COPY, RC_INIT, READ, WRITE
from repro.core.emulator import Trace


def addr_to_bank_row(addrs, geo: Geometry):
    """Physical->DRAM mapping: row-interleaved across banks (XOR mix)."""
    addrs = np.asarray(addrs, np.int64)
    rbuf = addrs // geo.row_bytes
    bank = (rbuf ^ (rbuf >> 4)) % geo.n_banks
    row = (rbuf // geo.n_banks) % geo.n_rows
    return bank.astype(np.int32), row.astype(np.int32)


def dram_trace_from_stream(addrs, writes, geo: Geometry, delta=8, window_dep=0):
    bank, row = addr_to_bank_row(addrs, geo)
    n = len(addrs)
    kind = np.where(np.asarray(writes), WRITE, READ).astype(np.int32)
    return Trace.of(kind=kind, bank=bank, row=row,
                    delta=np.full(n, delta, np.int32),
                    dep=np.full(n, window_dep, np.int32))


def iter_windows(trace: Trace, window: int) -> Iterator[Trace]:
    """Slice a materialized trace into bounded windows (views, no
    copies) — the shim between whole-trace generators and the
    streaming driver. ``emulator.run_stream(iter_windows(tr, w), ...)``
    is bit-identical to ``run(tr, ...)`` for any window size."""
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    for s in range(0, trace.n, window):
        e = min(s + window, trace.n)
        yield Trace(kind=trace.kind[s:e], bank=trace.bank[s:e],
                    row=trace.row[s:e], delta=trace.delta[s:e],
                    dep=trace.dep[s:e])


# ---------------- text trace files (workload zoo, ROADMAP item 1) ------

_READ_TOKENS = frozenset(
    ["r", "rd", "read", "readreq", "readex", "ld", "load", "l", "p",
     "pim", "ifetch"])
_WRITE_TOKENS = frozenset(
    ["w", "wr", "write", "writereq", "writeback", "wb", "st", "store",
     "s"])


def _parse_int(tok: str, path: str, lineno: int) -> int:
    try:
        return int(tok, 0)   # decimal or 0x... hex
    except ValueError:
        raise ValueError(
            f"{path}:{lineno}: expected an address, got {tok!r}") from None


def _parse_op(tok: str) -> Optional[bool]:
    """R/W command token -> is_write, or None if not a command."""
    t = tok.lower()
    if t in _READ_TOKENS:
        return False
    if t in _WRITE_TOKENS:
        return True
    return None


def parse_trace_line(line: str, path: str = "<trace>",
                     lineno: int = 0) -> Optional[tuple]:
    """Parse one text-trace line into ``(addr, is_write)``; None for
    blanks and ``#``/``//`` comments. Accepted layouts (whitespace- or
    comma-separated, hex or decimal addresses):

    * ramulator style: ``<addr>`` | ``<addr> <R|W>`` | ``<R|W> <addr>``
    * MemTraceProbe/CSV style: ``<tick>, <cmd>, <addr>[, <size>]``
      (cmd spelled ReadReq / WriteReq / rd / wr / ...)

    Anything else raises a ValueError naming the file, line number and
    offending text."""
    s = line.split("#", 1)[0].split("//", 1)[0].strip()
    if not s:
        return None
    toks = s.replace(",", " ").split()
    if len(toks) == 1:
        return _parse_int(toks[0], path, lineno), False
    if len(toks) == 2:
        w = _parse_op(toks[1])
        if w is not None:
            return _parse_int(toks[0], path, lineno), w
        w = _parse_op(toks[0])
        if w is not None:
            return _parse_int(toks[1], path, lineno), w
    elif len(toks) in (3, 4):
        w = _parse_op(toks[1])
        if w is not None:  # tick, cmd, addr[, size]
            return _parse_int(toks[2], path, lineno), w
    raise ValueError(
        f"{path}:{lineno}: unrecognized trace line {line.strip()!r} "
        f"(expected '<addr> <R|W>' or '<tick>, <cmd>, <addr>')")


def iter_trace_requests(path: str,
                        max_requests: Optional[int] = None) -> Iterator[tuple]:
    """Lazily yield ``(addr, is_write)`` from a text trace file.
    ``.gz`` files decompress transparently (production traces ship
    compressed); parse errors still carry the real file:lineno."""
    seen = 0
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as fh:
        for lineno, line in enumerate(fh, 1):
            if max_requests is not None and seen >= max_requests:
                return
            parsed = parse_trace_line(line, path, lineno)
            if parsed is None:
                continue
            seen += 1
            yield parsed


def load_trace_file(path: str, geo: Geometry, delta: int = 8,
                    window_dep: int = 0, llc: Optional[LLC] = None,
                    max_requests: Optional[int] = None) -> Trace:
    """Parse a whole ramulator-/MemTraceProbe-style text trace (plain
    or gzip ``.gz``) into one
    :class:`Trace` via :func:`dram_trace_from_stream`. ``llc`` (an
    optional cache model) filters the CPU-level stream down to DRAM
    traffic first. For files too large to materialize, use
    :func:`iter_trace_file_windows` with the streaming driver."""
    pairs = list(iter_trace_requests(path, max_requests))
    if not pairs:
        return Trace.of(kind=np.empty(0, np.int32), bank=np.empty(0),
                        row=np.empty(0), delta=np.empty(0))
    addrs = np.array([a for a, _ in pairs], np.int64)
    writes = np.array([w for _, w in pairs], bool)
    if llc is not None:
        addrs, writes, _ = filter_stream(addrs, writes, llc)
        if len(addrs) == 0:
            return Trace.of(kind=np.empty(0, np.int32), bank=np.empty(0),
                            row=np.empty(0), delta=np.empty(0))
    return dram_trace_from_stream(addrs, writes, geo, delta=delta,
                                  window_dep=window_dep)


def iter_trace_file_windows(path: str, geo: Geometry, window: int = 4096,
                            delta: int = 8, window_dep: int = 0,
                            llc: Optional[LLC] = None,
                            max_requests: Optional[int] = None,
                            ) -> Iterator[Trace]:
    """Windowed variant of :func:`load_trace_file` for the streaming
    driver: reads ``window`` requests at a time and yields each batch
    as a :class:`Trace`, holding O(window) memory however long the
    file is. A provided ``llc`` is stateful ACROSS windows (the same
    object filters the whole stream), so the concatenated output
    equals the single-shot :func:`load_trace_file` exactly — windows
    just come out shorter where the cache absorbs accesses."""
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    addrs, writes = [], []

    def flush():
        a = np.array(addrs, np.int64)
        w = np.array(writes, bool)
        addrs.clear()
        writes.clear()
        if llc is not None:
            a, w, _ = filter_stream(a, w, llc)
        if len(a) == 0:
            return None
        return dram_trace_from_stream(a, w, geo, delta=delta,
                                      window_dep=window_dep)

    for addr, is_write in iter_trace_requests(path, max_requests):
        addrs.append(addr)
        writes.append(is_write)
        if len(addrs) == window:
            tr = flush()
            if tr is not None:
                yield tr
    if addrs:
        tr = flush()
        if tr is not None:
            yield tr


def synthetic_stream(n_requests: int, window: int = 4096, seed: int = 0,
                     n_banks: int = 16, n_rows: int = 4096,
                     kinds: int = 2, delta_max: int = 8,
                     dep_max: int = 2) -> Iterator[Trace]:
    """Unbounded-style random request stream, yielded one ``window`` at
    a time so the whole trace never materializes — the 1M-request
    steady-state workload of ``benchmarks --section streaming``. The
    per-window RNG is seeded by (seed, window index): the stream is
    reproducible and restartable, and its distribution matches the
    8x4000 single-shot steady-state traces in benchmarks/paper.py
    (uniform banks/rows, read/write mix, delta in [1, delta_max),
    dep in [0, dep_max))."""
    emitted = 0
    k = 0
    while emitted < n_requests:
        m = min(window, n_requests - emitted)
        rng = np.random.RandomState((seed * 1_000_003 + k) % (2 ** 31))
        yield Trace.of(kind=rng.randint(0, kinds, m),
                       bank=rng.randint(0, n_banks, m),
                       row=rng.randint(0, n_rows, m),
                       delta=rng.randint(1, delta_max, m),
                       dep=rng.randint(0, dep_max, m))
        emitted += m
        k += 1


def rowhammer_trace(n_requests: int, geo: Geometry, hammer_row: int = 128,
                    hammer_bank: int = 0, intensity: float = 0.8,
                    double_sided: bool = True, seed: int = 0,
                    delta_max: int = 8) -> Trace:
    """Aggressor-access storm for the fault-injection model
    (``core.faults.FaultModel``): a fraction ``intensity`` of the
    requests are row-conflicting ACT hammers on ``hammer_row`` (and
    ``hammer_row + 2`` when ``double_sided`` — both neighbor the victim
    ``hammer_row + 1``), the rest are uniform background traffic on the
    OTHER banks, so every background access leaves the aggressor bank's
    open-row state alone and each hammer pair forces a fresh
    activation. Deterministic in ``seed``; ``intensity`` is the sweep
    axis of ``techniques.RowHammerMitigationStudy``."""
    if not 0.0 <= intensity <= 1.0:
        raise ValueError(f"intensity must be in [0, 1], got {intensity}")
    rng = np.random.RandomState(seed)
    hammer = rng.rand(n_requests) < intensity
    # alternate between the two aggressors so consecutive hammers are
    # always row misses (single-sided alternates with a far decoy row)
    alt = np.cumsum(hammer) % 2
    other = hammer_row + 2 if double_sided \
        else (hammer_row + geo.n_rows // 2) % geo.n_rows
    row = np.where(hammer, np.where(alt == 0, hammer_row, other),
                   rng.randint(0, geo.n_rows, n_requests))
    bg_bank = (hammer_bank + rng.randint(1, max(2, geo.n_banks),
                                         n_requests)) % geo.n_banks
    bank = np.where(hammer, hammer_bank, bg_bank)
    kind = np.where(hammer, READ, rng.randint(0, 2, n_requests))
    return Trace.of(kind=kind.astype(np.int32), bank=bank, row=row,
                    delta=rng.randint(1, delta_max, n_requests),
                    dep=np.zeros(n_requests, np.int32))


# ---------------- microbenchmarks ----------------

def pointer_chase(n_bytes: int, geo: Geometry, stride=64, n_loads=4096,
                  compute_delta=4, llc: LLC = None, seed=0):
    """lmbench-style memory read latency benchmark over an n_bytes region.

    Dependent loads (dep=1): each load's address depends on the previous
    response — the latency-revealing access pattern of Fig. 8."""
    rng = np.random.RandomState(seed)
    n_lines = max(n_bytes // stride, 1)
    perm = rng.permutation(n_lines)
    addrs = (perm[np.arange(n_loads) % n_lines] * stride).astype(np.int64)
    da, dw, _ = filter_stream(addrs, np.zeros(len(addrs), bool), llc or LLC())
    if len(da) == 0:  # fully cache-resident
        return None
    tr = dram_trace_from_stream(da, dw, geo, delta=compute_delta)
    tr.dep[:] = 1  # chase: every DRAM access depends on the previous one
    return tr, len(addrs), len(da)


def copy_workload(n_bytes: int, geo: Geometry, mode: str, device=None,
                  setting: str = "noflush", alloc_base_row: int = 64,
                  cpu_line_delta: int = 6):
    """Copy an n_bytes source array into a destination array.

    mode: 'cpu' (load/store per line) or 'rowclone' (FPM copy per row,
    with CPU fallback on unclonable pairs). setting: 'noflush' |
    'clflush' (dirty source lines must be written back first).
    Returns (Trace, meta)."""
    lines = max(n_bytes // geo.line_bytes, 1)
    rows = max(n_bytes // geo.row_bytes, 1)
    kinds, banks, rws, deltas, deps = [], [], [], [], []
    meta = {"fallback_rows": 0, "rows": rows}

    def emit(kind, bank, row, delta, dep=0):
        kinds.append(kind)
        banks.append(bank)
        rws.append(row)
        deltas.append(delta)
        deps.append(dep)

    if setting == "clflush":
        # write back dirty cached copies of the source (worst case: all)
        for i in range(lines):
            ri = (i * geo.line_bytes) // geo.row_bytes
            bank = ri % geo.n_banks
            srow = (alloc_base_row + 2 * (ri // geo.n_banks)) % geo.n_rows
            emit(WRITE, bank, srow, 2)

    # RowClone-aware allocation (Sec. 7.1): rows pair within the SAME bank
    # and 512-row subarray; the allocator *profiles* candidate (src, dst)
    # pairs (the paper's 1000-op test) and only assigns clonable ones, so
    # CPU fallback happens just when no candidate in the subarray works.
    def pair(i):
        bank = i % geo.n_banks
        srow = (alloc_base_row + 2 * (i // geo.n_banks)) % geo.n_rows
        if device is None:
            return bank, srow, srow + 1
        sa = geo.subarray_rows
        sa_base = (srow // sa) * sa
        for off in range(1, 9):  # profile up to 8 candidate destinations
            drow = sa_base + (srow - sa_base + off) % sa
            if device.clonable(bank, int(srow), int(drow)):
                return bank, srow, drow
        return bank, srow, srow + 1  # profiling failed -> fallback pair

    if mode == "cpu":
        # CPU baseline uses a NORMAL allocation: src/dst regions interleave
        # across banks at row granularity (streaming row hits, no forced
        # same-bank ping-pong)
        for i in range(lines):
            ri = (i * geo.line_bytes) // geo.row_bytes
            # dst region offset co-prime with the bank count so src/dst
            # streams occupy different banks (as a real interleaver does)
            sr = alloc_base_row + ri
            dr = alloc_base_row + 2 * rows + geo.n_banks // 2 + 1 + ri
            emit(READ, sr % geo.n_banks, sr // geo.n_banks % geo.n_rows,
                 cpu_line_delta)
            emit(WRITE, dr % geo.n_banks, dr // geo.n_banks % geo.n_rows,
                 cpu_line_delta)
    else:
        for i in range(rows):
            bank, srow, drow = pair(i)
            ok = device is None or device.clonable(bank, int(srow), int(drow))
            if ok:
                # synchronous driver call: each RC op waits for completion
                emit(RC_COPY, bank, drow, 12, dep=1)
            else:  # CPU fallback for this row
                meta["fallback_rows"] += 1
                for j in range(geo.lines_per_row):
                    emit(READ, bank, srow, cpu_line_delta)
                    emit(WRITE, bank, drow, cpu_line_delta)
    return Trace.of(kinds, banks, rws, deltas, deps), meta


def init_workload(n_bytes: int, geo: Geometry, mode: str, device=None,
                  setting: str = "noflush", alloc_base_row: int = 8192,
                  cpu_line_delta: int = 4):
    """Initialize an n_bytes array with a pattern (one source row per
    subarray, cloned into every destination row)."""
    lines = max(n_bytes // geo.line_bytes, 1)
    rows = max(n_bytes // geo.row_bytes, 1)
    kinds, banks, rws, deltas, deps = [], [], [], [], []
    meta = {"fallback_rows": 0, "rows": rows}

    def emit(kind, bank, row, delta, dep=0):
        kinds.append(kind)
        banks.append(bank)
        rws.append(row)
        deltas.append(delta)
        deps.append(dep)

    if setting == "clflush":
        for i in range(rows):  # invalidate destination rows' cached lines
            r = alloc_base_row + i
            emit(WRITE, r % geo.n_banks, r // geo.n_banks % geo.n_rows, 1)

    if mode == "cpu":
        for i in range(lines):
            drow = alloc_base_row + (i * geo.line_bytes) // geo.row_bytes
            emit(WRITE, drow % geo.n_banks, drow // geo.n_banks % geo.n_rows,
                 cpu_line_delta)
    else:
        for i in range(rows):
            dr = alloc_base_row + i
            bank = dr % geo.n_banks
            drow = dr // geo.n_banks % geo.n_rows
            sa = geo.subarray_rows
            sa_base = (drow // sa) * sa  # one source row per subarray
            ok = False
            for off in (0, 1, 2, 3):     # profile a few source candidates
                if device is None or device.clonable(bank, int(sa_base + off), int(drow)):
                    ok = True
                    break
            if ok:
                emit(RC_INIT, bank, drow, 12, dep=1)
            else:
                meta["fallback_rows"] += 1
                for j in range(geo.lines_per_row):
                    emit(WRITE, bank, drow, cpu_line_delta)
    return Trace.of(kinds, banks, rws, deltas, deps), meta


# ---------------- PolyBench-like kernels ----------------

@dataclasses.dataclass(frozen=True)
class Kernel:
    name: str
    arrays: tuple          # (n_bytes, stride, passes) per array
    compute_per_access: int
    dep: int = 0           # 1 = loop-carried dependence (latency-bound)


# spread of memory intensity mirroring the suite (durbin ~0.01 LLC MPKC,
# gemm blocked reuse, streaming stencils, etc.)
POLYBENCH = (
    Kernel("gemm",       ((1 << 21, 64, 2), (1 << 21, 64, 2), (1 << 20, 64, 1)), 48),
    Kernel("2mm",        ((1 << 21, 64, 2), (1 << 21, 64, 2), (1 << 21, 64, 2)), 40),
    Kernel("3mm",        ((1 << 21, 64, 3), (1 << 21, 64, 2), (1 << 21, 64, 2)), 40),
    Kernel("atax",       ((1 << 22, 64, 2), (1 << 16, 64, 4)), 10),
    Kernel("bicg",       ((1 << 22, 64, 2), (1 << 16, 64, 4)), 10),
    Kernel("mvt",        ((1 << 22, 64, 2), (1 << 16, 64, 2)), 10),
    Kernel("gemver",     ((1 << 22, 64, 3), (1 << 16, 64, 2)), 14),
    Kernel("gesummv",    ((1 << 22, 64, 2), (1 << 16, 64, 2)), 8),
    Kernel("syrk",       ((1 << 21, 64, 2), (1 << 20, 64, 2)), 36),
    Kernel("syr2k",      ((1 << 21, 64, 3), (1 << 20, 64, 2)), 32),
    Kernel("trmm",       ((1 << 21, 64, 2),), 30),
    Kernel("symm",       ((1 << 21, 64, 2), (1 << 20, 64, 2)), 34),
    Kernel("cholesky",   ((1 << 21, 64, 2),), 26, dep=1),
    Kernel("lu",         ((1 << 21, 64, 3),), 24, dep=1),
    Kernel("ludcmp",     ((1 << 21, 64, 3), (1 << 16, 64, 2)), 24, dep=1),
    Kernel("trisolv",    ((1 << 20, 64, 2), (1 << 16, 64, 2)), 8, dep=1),
    Kernel("durbin",     ((1 << 15, 64, 8),), 12, dep=1),
    Kernel("gramschmidt", ((1 << 21, 64, 3),), 28, dep=1),
    Kernel("correlation", ((1 << 21, 64, 3),), 22),
    Kernel("covariance", ((1 << 21, 64, 3),), 22),
    Kernel("jacobi-1d",  ((1 << 21, 64, 4),), 6),
    Kernel("jacobi-2d",  ((1 << 21, 64, 4),), 8),
    Kernel("seidel-2d",  ((1 << 21, 64, 4),), 10, dep=1),
    Kernel("heat-3d",    ((1 << 21, 64, 4),), 10),
    Kernel("fdtd-2d",    ((1 << 21, 64, 4),), 9),
    Kernel("adi",        ((1 << 21, 64, 4),), 14, dep=1),
    Kernel("doitgen",    ((1 << 21, 64, 2), (1 << 16, 64, 4)), 20),
    Kernel("deriche",    ((1 << 21, 64, 4),), 12),
)


def polybench_stream(kern: Kernel, max_accesses=60000, seed=0):
    """CPU-level address stream for a kernel: interleaved strided passes."""
    rng = np.random.RandomState(seed + hash(kern.name) % 1000)
    streams = []
    base = 0
    for (nb, stride, passes) in kern.arrays:
        lines = nb // stride
        for p in range(passes):
            a = base + (np.arange(lines) * stride)
            if kern.name in ("gemm", "2mm", "3mm", "syrk", "syr2k", "symm"):
                # blocked reuse: revisit tiles
                tile = max(lines // 16, 1)
                idx = np.concatenate([np.tile(np.arange(i, min(i + tile, lines)), 3)
                                      for i in range(0, lines, tile)])
                a = base + idx * stride
            streams.append(a)
        base += nb * 2
    n = min(max_accesses, sum(len(s) for s in streams))
    # round-robin interleave the array passes
    out = np.empty(n, np.int64)
    k = len(streams)
    ptrs = [0] * k
    for i in range(n):
        j = i % k
        s = streams[j]
        out[i] = s[ptrs[j] % len(s)]
        ptrs[j] += 1
    writes = rng.rand(n) < 0.3
    return out, writes


def polybench_trace(kern: Kernel, geo: Geometry, max_accesses=60000, seed=0):
    addrs, writes = polybench_stream(kern, max_accesses, seed)
    da, dw, llc = filter_stream(addrs, writes)
    if len(da) == 0:
        return None, 0
    tr = dram_trace_from_stream(da, dw, geo, delta=kern.compute_per_access,
                                window_dep=kern.dep)
    return tr, len(addrs)


# ---------------- LM-step traces ----------------

def lm_decode_trace(cfg, seq_len: int, geo: Geometry, max_requests=20000,
                    hbm_like_delta=2):
    """DRAM traffic of one decode step: stream active params + KV reads.

    Rows are touched sequentially (weights stream) and KV reads scatter
    across banks — the arithmetic-intensity-realistic trace the serve
    engine hands to the emulator."""
    from repro.models import model_zoo
    model = model_zoo.build(cfg, s_max=max(seq_len, 16))
    n_params = model.n_params()
    if cfg.moe:
        act_frac = (cfg.moe.top_k / cfg.moe.n_experts)
        n_active = int(n_params * (0.25 + 0.75 * act_frac))
    else:
        n_active = n_params
    weight_rows = min(n_active * 2 // geo.row_bytes, max_requests * 3 // 4)
    kv_lines = 0
    if not cfg.attn_free:
        attn_layers = max(cfg.n_layers // cfg.attn_every, 1)
        kv_bytes = (attn_layers * 2 * cfg.n_kv_heads *
                    cfg.resolved_head_dim * seq_len * 2)
        kv_lines = min(kv_bytes // geo.line_bytes, max_requests // 4)
    kinds, banks, rows, deltas = [], [], [], []
    for i in range(int(weight_rows)):
        kinds.append(READ)
        banks.append(i % geo.n_banks)
        rows.append((i // geo.n_banks) % geo.n_rows)
        deltas.append(hbm_like_delta)
    rng = np.random.RandomState(3)
    for i in range(int(kv_lines)):
        kinds.append(READ)
        banks.append(int(rng.randint(geo.n_banks)))
        rows.append(int(rng.randint(geo.n_rows // 2, geo.n_rows)))
        deltas.append(hbm_like_delta)
    return Trace.of(kinds, banks, rows, deltas)


def kv_fork_trace(n_pages: int, page_bytes: int, geo: Geometry, mode: str,
                  device=None):
    """KV-cache page fork (prefix sharing / beam split) as bulk copy —
    the serving-side RowClone use case."""
    return copy_workload(n_pages * page_bytes, geo, mode=mode, device=device,
                         setting="noflush", alloc_base_row=16384)
