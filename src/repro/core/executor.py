"""Overlapped campaign executor: run prepared compile-key groups with
host/device overlap instead of the serial pack -> dispatch -> block loop.

Why a thread pool and not async dispatch: on XLA:CPU under the inline
runtime (``jax_compat.enable_fast_cpu_scan``) an executable runs
synchronously on the calling thread, so ``fn(*args)`` only returns after
the scan finishes — there is nothing to overlap from one Python thread.
XLA does release the GIL for the whole execution, though, so two
*threads* genuinely overlap: while a worker is inside XLA running group
k, another worker packs (``np.stack`` / padding, pure Python+NumPy) and
then executes group k+1 on the second core. Measured on the emulator
scan this is ~1.6-1.9x over the serial loop on a 2-core host, scaling
with cores until group compute is exhausted.

Determinism contract:

* A :class:`GroupTask` is *prepared* on the caller's thread — in
  particular :func:`repro.core.emulator._batched_fn` (the in-memory
  executable LRU) is resolved before any worker starts, so
  ``cache_stats()`` counters are exactly what the serial loop would
  produce, in the same order.
* Each task's ``finalize`` writes only its own result slots (disjoint
  indices of a shared list), so concurrent finalization needs no lock.
* Execution is bit-identical to the serial loop by construction: the
  same executable runs on the same packed arrays; only wall-clock
  interleaving changes. ``execute(tasks, serial=True)`` keeps the PR 4
  in-order loop for A/B (``benchmarks --section executor_speed``).

The pool is module-level and lazily built (``REPRO_EXEC_WORKERS`` caps
it, default ``min(cpu_count, 8)``); :func:`set_workers` resizes it.
Worker threads only ever touch jax through executable calls and
``jnp.asarray`` staging, both thread-safe.
"""
from __future__ import annotations

import atexit
import dataclasses
import os
import threading
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["GroupTask", "StreamTask", "TaskFailure", "ExecutionError",
           "execute", "submit_task", "set_workers", "workers",
           "shutdown", "is_shutdown"]


@dataclasses.dataclass
class GroupTask:
    """One compile-key group, prepared but not yet executed.

    ``fn`` is the resolved (jitted, possibly shard_mapped) batched
    executable; ``pack`` builds its argument arrays on the host and
    returns ``(args, ctx)``; ``finalize`` receives the gathered NumPy
    outputs plus ``ctx`` and writes per-trace records into the
    caller's result slots. ``pack`` and ``finalize`` run on a worker
    thread under :func:`execute`'s overlapped mode — keep them free of
    shared mutable state beyond the disjoint result slots.
    """
    fn: Callable[..., Any]
    pack: Callable[[], Tuple[tuple, Any]]
    finalize: Callable[[dict, Any], None]
    label: str = ""
    cost: int = 0   # relative work hint (e.g. slots * batch) for LPT order

    # pack() re-pads and re-stacks from the immutable prepared traces and
    # finalize() overwrites the same disjoint slots, so a failed attempt
    # can safely be retried from scratch (transient-failure recovery)
    retryable = True

    def run(self) -> None:
        args, ctx = self.pack()                      # host: pad + stack
        out = self.fn(*args)                         # device: the scan
        out = {k: np.asarray(v) for k, v in out.items()}  # gather (blocks)
        self.finalize(out, ctx)


@dataclasses.dataclass
class StreamTask:
    """One streaming compile-key group: a window loop instead of a
    single dispatch (see ``repro.core.emulator.prepare_stream_tasks``).

    ``pack`` builds the initial carried state plus a host context;
    ``windows(ctx)`` yields one argument tuple per trace window (the
    last one freeze-lifted to drain the tail in place);
    ``fn(state, *args)`` is the resolved window
    executable returning ``(new_state, emitted)``; ``consume`` receives
    each window's gathered NumPy emission; ``finalize`` receives the
    final carried state. The loop is inherently serial per task — state
    threads window to window — but host and device still overlap WITHIN
    it: window assembly (trace generation / file parsing, ``np.stack``,
    staging) runs on a dedicated prefetch thread one window ahead while
    the current window is inside XLA (which releases the GIL for the
    whole execution — the same observation the group-level pool is
    built on). The prefetch queue is bounded, so a stream holds at most
    ``_PREFETCH`` staged windows at once — constant memory, whatever
    the trace length. The executor additionally overlaps DIFFERENT
    stream/group tasks across workers. Same determinism contract as
    :class:`GroupTask`: disjoint result slots, prepared on the
    caller's thread; prefetch changes wall-clock interleaving only,
    never the window sequence."""
    fn: Callable[..., Any]
    pack: Callable[[], Tuple[Any, Any]]
    windows: Callable[[Any], Any]        # ctx -> iterable of arg tuples
    consume: Callable[[tuple, Any], None]
    finalize: Callable[[Any, Any], None]
    label: str = ""
    cost: int = 0

    # a failed window loop cannot be replayed: the stream iterators and
    # chunker buffers are partially consumed — never auto-retry
    retryable = False

    _PREFETCH = 2  # max staged windows in flight (bounds memory)

    def run(self) -> None:
        import queue as _queue

        state, ctx = self.pack()
        q: _queue.Queue = _queue.Queue(maxsize=self._PREFETCH)
        done, stop = object(), threading.Event()

        def put(item) -> bool:
            # _SHUTDOWN poisons the feed at interpreter exit: a prefetch
            # thread mid-stream must not keep generating windows (or
            # block forever on a full queue) while the process tears down
            while not stop.is_set() and not _SHUTDOWN.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except _queue.Full:
                    continue
            return False

        def feed() -> None:
            try:
                for args in self.windows(ctx):
                    if not put(args):
                        return          # consumer bailed; stop generating
                put(done)
            except BaseException as e:  # surface on the consuming thread
                put(e)

        th = threading.Thread(target=feed, daemon=True,
                              name="repro-stream-prefetch")
        th.start()
        try:
            while True:
                try:
                    args = q.get(timeout=0.2)
                except _queue.Empty:
                    # a poisoned feeder (interpreter shutdown) never
                    # delivers its `done` sentinel — fail the window
                    # loop instead of blocking a non-daemon pool worker
                    # forever (which would deadlock interpreter exit)
                    if _SHUTDOWN.is_set():
                        raise RuntimeError(
                            f"stream task {self.label or 'task'!r} "
                            f"aborted: executor shut down at interpreter "
                            f"exit")
                    continue
                if args is done:
                    break
                if isinstance(args, BaseException):
                    raise args
                state, out = self.fn(state, *args)   # device: one window
                self.consume(tuple(np.asarray(o) for o in out), ctx)
        finally:
            # deterministic shutdown: signal stop, then DRAIN the queue
            # while joining — a feeder sitting in q.put() frees its slot
            # immediately instead of burning its 0.1s put-timeout per
            # queued window, and the loop converges however many windows
            # are in flight. The deadline only guards a feeder stuck
            # inside the user's window generator (next() cannot be
            # interrupted from outside); that pathological case is
            # reported, not silently leaked.
            stop.set()
            deadline = time.monotonic() + 5.0
            while th.is_alive() and time.monotonic() < deadline:
                try:
                    q.get_nowait()
                except _queue.Empty:
                    pass
                th.join(timeout=0.02)
            if th.is_alive():  # pragma: no cover - needs a hung generator
                warnings.warn(
                    f"stream prefetch thread for {self.label or 'task'!r} "
                    f"did not stop within 5s (window generator blocked); "
                    f"leaking a daemon thread", RuntimeWarning)
        self.finalize(state, ctx)


def _env_int(name: str, default: int) -> int:
    """Parse an integer env knob; a bad value must not kill library
    import — warn with the offending value and fall back."""
    env = os.environ.get(name)
    if not env:
        return default
    try:
        return int(env)
    except ValueError:
        import warnings
        warnings.warn(f"ignoring non-integer {name}={env!r}; "
                      f"using default {default}", stacklevel=2)
        return default


def _workers_default() -> int:
    return max(1, _env_int("REPRO_EXEC_WORKERS",
                           min(os.cpu_count() or 1, 8)))


_LOCK = threading.Lock()
_POOL: Optional[ThreadPoolExecutor] = None
_WORKERS = _workers_default()
# set once, at interpreter exit (or by an explicit shutdown()): poisons
# StreamTask prefetch feeds and queue waits so no worker blocks teardown
_SHUTDOWN = threading.Event()


def workers() -> int:
    """Current overlapped-execution worker count."""
    return _WORKERS


def set_workers(n: int) -> int:
    """Resize the worker pool; returns the previous count. ``n <= 1``
    makes :func:`execute` fall back to the serial in-order loop."""
    global _POOL, _WORKERS
    if n < 1:
        raise ValueError(f"worker count must be >= 1, got {n}")
    with _LOCK:
        old = _WORKERS
        _SHUTDOWN.clear()   # re-arm after an explicit shutdown() (tests)
        if n != _WORKERS:
            if _POOL is not None:
                _POOL.shutdown(wait=True)
                _POOL = None
            _WORKERS = n
    return old


def _pool() -> ThreadPoolExecutor:
    global _POOL
    with _LOCK:
        if _SHUTDOWN.is_set():
            raise RuntimeError(
                "executor pool is shut down (interpreter exit or explicit "
                "executor.shutdown()); no further dispatches accepted")
        if _POOL is None:
            _POOL = ThreadPoolExecutor(
                max_workers=_WORKERS, thread_name_prefix="repro-exec")
        return _POOL


def is_shutdown() -> bool:
    """True once the executor has been poisoned (interpreter exit or an
    explicit :func:`shutdown`); new dispatches are refused."""
    return _SHUTDOWN.is_set()


def shutdown(wait: bool = False) -> None:
    """Drain/poison the executor for process teardown.

    Ordering matters at interpreter exit: ThreadPoolExecutor's own
    threading hook JOINS its (non-daemon) worker threads, so any worker
    blocked on a queue — a StreamTask consumer whose prefetch feeder
    died, a feeder stuck in ``q.put`` — would deadlock ``python`` on
    exit, and a killed client could leave a server's dispatch threads
    holding the device indefinitely. This runs FIRST (module ``atexit``
    handlers precede threading's join of non-daemon threads): it poisons
    the StreamTask feed/consume loops via the module event, cancels
    queued-but-unstarted tasks, and lets in-flight XLA executions finish
    on their own (they cannot be interrupted, only awaited). Idempotent;
    :func:`set_workers` after an explicit shutdown re-arms the pool."""
    global _POOL
    _SHUTDOWN.set()
    with _LOCK:
        pool, _POOL = _POOL, None
    if pool is not None:
        pool.shutdown(wait=wait, cancel_futures=True)


atexit.register(shutdown)


@dataclasses.dataclass
class TaskFailure:
    """One task that did not complete: the task object, its label, the
    exception from its final attempt, and how many attempts ran (0 for
    a dispatch timeout — the attempt never settled)."""
    task: Any
    label: str
    error: BaseException
    attempts: int


class ExecutionError(RuntimeError):
    """Aggregate of every failed task in one :func:`execute` call. The
    message names EVERY failed group label (a sweep debugging session
    should not need N reruns to see N failures) and carries the first
    underlying error's text; ``failures`` holds the full records."""

    def __init__(self, failures: Sequence[TaskFailure]):
        self.failures = list(failures)
        labels = ", ".join(
            (f.label or f"task{i}") for i, f in enumerate(self.failures))
        first = self.failures[0].error
        super().__init__(
            f"{len(self.failures)} task(s) failed [{labels}]; first: "
            f"{type(first).__name__}: {first}")


def _attempt(task: Any, retries: int, backoff: float
             ) -> Optional[TaskFailure]:
    """Run one task to completion with bounded retry-with-backoff.
    Only ``task.retryable`` tasks are re-attempted (GroupTask packing is
    idempotent; a StreamTask's iterators are consumed). Returns None on
    success, else the failure record — never raises."""
    attempts = 0
    while True:
        attempts += 1
        try:
            task.run()
            return None
        except BaseException as e:
            if not getattr(task, "retryable", False) or attempts > retries:
                return TaskFailure(task, getattr(task, "label", ""),
                                   e, attempts)
            time.sleep(backoff * (2 ** (attempts - 1)))


def submit_task(task: Any, retries: Optional[int] = None,
                backoff: Optional[float] = None) -> "Future":
    """Asynchronous single-task entry point (what the sweep service's
    dispatcher uses): submit one PREPARED task to the overlapped worker
    pool and return its :class:`concurrent.futures.Future`, which
    resolves to ``None`` on success or a :class:`TaskFailure` record —
    never an exception (same ``_attempt`` semantics as :func:`execute`,
    including bounded retry-with-backoff for retryable tasks). The
    caller owns result demultiplexing: the task's ``finalize`` has run
    by the time the future resolves ``None``. Raises ``RuntimeError``
    after :func:`shutdown` (teardown refuses new dispatches)."""
    if retries is None:
        retries = max(0, _env_int("REPRO_EXEC_RETRIES", 0))
    if backoff is None:
        backoff = float(os.environ.get("REPRO_EXEC_BACKOFF_S", "") or 0.05)
    return _pool().submit(_attempt, task, retries, backoff)


def execute(tasks: Sequence[Any], serial: Optional[bool] = None,
            timeout: Optional[float] = None, retries: Optional[int] = None,
            backoff: Optional[float] = None,
            raise_on_error: bool = True) -> List[TaskFailure]:
    """Run every task; overlapped across the worker pool unless
    ``serial`` (or a single task / single worker) forces the in-order
    loop. Tasks were prepared in submission order on the caller's
    thread, so compile-cache counters are already settled; execution
    order does not affect results (disjoint result slots).

    Failure isolation: a raising task never stops its siblings — every
    task settles, failures are collected into :class:`TaskFailure`
    records, and (``raise_on_error``, the default) one
    :class:`ExecutionError` naming every failed label is raised at the
    end; ``raise_on_error=False`` returns the records instead (what
    ``Campaign.run(on_error='quarantine')`` uses).

    Transient-failure recovery: ``retries`` (default
    ``REPRO_EXEC_RETRIES``, 0) re-attempts each *retryable* task with
    exponential backoff starting at ``backoff`` seconds (default
    ``REPRO_EXEC_BACKOFF_S``, 0.05). ``timeout`` (default
    ``REPRO_EXEC_TIMEOUT_S``, none) bounds each task's wall time in
    overlapped mode: a task past its deadline is recorded as a
    ``TimeoutError`` failure and ABANDONED — Python threads cannot be
    killed, so its worker keeps running detached (it may still write
    its disjoint result slots later); treat timed-out sweeps' result
    lists as tainted and re-dispatch. In serial mode there is no second
    thread to watch the clock, so ``timeout`` is not enforced."""
    tasks = list(tasks)
    if retries is None:
        retries = max(0, _env_int("REPRO_EXEC_RETRIES", 0))
    if backoff is None:
        backoff = float(os.environ.get("REPRO_EXEC_BACKOFF_S", "") or 0.05)
    if timeout is None:
        env_t = os.environ.get("REPRO_EXEC_TIMEOUT_S")
        timeout = float(env_t) if env_t else None
    if serial is None:
        serial = len(tasks) <= 1 or _WORKERS <= 1

    failures: List[TaskFailure] = []
    if serial:
        for t in tasks:
            fail = _attempt(t, retries, backoff)
            if fail is not None:
                failures.append(fail)
    else:
        # longest-processing-time-first: dispatching expensive groups
        # first minimizes the tail where one worker finishes a big group
        # alone (order is free to change — results land in disjoint slots)
        tasks.sort(key=lambda t: t.cost, reverse=True)
        starts: dict = {}

        def tracked(t):
            starts[id(t)] = time.monotonic()
            return _attempt(t, retries, backoff)

        pending = {_pool().submit(tracked, t): t for t in tasks}
        if timeout is None:
            for f in pending:           # block; _attempt never raises
                fail = f.result()
                if fail is not None:
                    failures.append(fail)
        else:
            while pending:              # poll so deadlines fire on time
                for f in list(pending):
                    t = pending[f]
                    started = starts.get(id(t))
                    if f.done():
                        del pending[f]
                        fail = f.result()
                        if fail is not None:
                            failures.append(fail)
                    elif started is not None \
                            and time.monotonic() - started > timeout:
                        del pending[f]  # abandon; see docstring
                        failures.append(TaskFailure(
                            t, getattr(t, "label", ""),
                            TimeoutError(
                                f"task {getattr(t, 'label', '')!r} "
                                f"exceeded the {timeout}s dispatch "
                                f"timeout"), 0))
                if pending:
                    time.sleep(0.005)

    if failures and raise_on_error:
        raise ExecutionError(failures)
    return failures
