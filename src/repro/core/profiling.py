"""Seeded DRAM device model (the 'real chip' the FPGA platform talks to).

There is no silicon here, so per-cell behavior comes from a deterministic
statistical model calibrated to the paper's reported aggregates:

* Fig. 12 — every row works below nominal tRCD (13.5 ns); 84.5% of cache
  lines are *strong* (reliable at <= 9.0 ns); weak lines cluster spatially
  (bank regions). We model a per-row minimum reliable tRCD as
  base + bank effect + smooth region effect + row noise.
* RowClone (Sec. 7) — FPM copy only works intra-subarray, and a few
  (src, dst) pairs fail chip-specifically; the allocator discovers this by
  profiling (1000-op test in the paper; a deterministic hash here).
"""
from __future__ import annotations

import numpy as np

from repro.core.dram import Geometry


class DeviceModel:
    def __init__(self, geo: Geometry, seed: int = 7, weak_target: float = 0.155,
                 clone_fail_rate: float = 0.02):
        self.geo = geo
        self.seed = seed
        rng = np.random.RandomState(seed)
        nb, nr = geo.n_banks, geo.n_rows
        region = geo.subarray_rows
        n_regions = nr // region
        # spatially clustered weakness: per-(bank, region) offset, smoothed
        bank_eff = rng.normal(0.0, 0.6, size=(nb, 1))
        reg = rng.normal(0.0, 1.0, size=(nb, n_regions))
        kern = np.array([0.25, 0.5, 1.0, 0.5, 0.25])
        reg = np.apply_along_axis(lambda v: np.convolve(v, kern, mode="same"), 1, reg)
        reg_eff = np.repeat(reg, region, axis=1)
        noise = rng.normal(0.0, 0.35, size=(nb, nr))
        score = bank_eff + reg_eff + noise
        # calibrate threshold so P(weak) == weak_target
        thr = np.quantile(score, 1.0 - weak_target)
        self.weak = score > thr                       # [banks, rows] bool
        # min reliable tRCD in ns: strong in [6, 9], weak in (9, 13.2]
        u = rng.uniform(size=(nb, nr))
        self.min_trcd_ns = np.where(self.weak, 9.2 + 4.0 * u, 6.0 + 3.0 * u)
        self._clone_fail_rate = clone_fail_rate

    def weak_fraction(self) -> float:
        return float(self.weak.mean())

    def weak_rows(self):
        """Global row ids (bank * n_rows + row) of weak rows."""
        b, r = np.nonzero(self.weak)
        return (b.astype(np.int64) * self.geo.n_rows + r).astype(np.int64)

    # ---- RowClone pair characterization ----
    def same_subarray(self, src_row, dst_row) -> bool:
        sa = self.geo.subarray_rows
        return (src_row // sa) == (dst_row // sa)

    def clonable(self, bank: int, src_row: int, dst_row: int) -> bool:
        """Deterministic 'profiled with 1000 copy ops' result."""
        if src_row == dst_row or not self.same_subarray(src_row, dst_row):
            return False
        h = 0x9E3779B97F4A7C15
        mask = (1 << 64) - 1
        x = (bank * 1000003) ^ (src_row * 8191) ^ (dst_row * 131071) ^ self.seed
        x = (x * h) & mask
        x ^= x >> 29
        x = (x * h) & mask
        x ^= x >> 32
        frac = x / float(2 ** 64)
        return frac >= self._clone_fail_rate

    def trcd_heatmap(self, banks=2, rows=4096):
        """Fig.12-style heatmap data: min reliable tRCD (ns)."""
        return self.min_trcd_ns[:banks, :rows]
