"""Software-defined SMC scheduling policies: a branchless MC-policy VM.

EasyDRAM's first key idea is that DRAM scheduling policies are *software*
running on a programmable memory controller (SMC) — not RTL. This module
reproduces that idea in jax_pallas terms: a scheduling policy is a tiny
program over a fixed register IR, authored in ~20 lines of Python with
:class:`PolicyBuilder`, assembled into a dense int32 instruction table
(:class:`PolicyProgram`), and evaluated *inside* the emulator's scan slot
body over the Q visible hardware-queue slots.

Execution model
---------------

Two execution paths share one semantics:

* **Staged constant** (PR 4): the table is a compile-time constant of
  the jitted emulator program; its content rides in the compile key
  through ``SystemConfig`` (a :class:`PolicyProgram` is hashed/compared
  by table content, not by name, so two same-content programs share one
  cached executable). The evaluator (:func:`evaluate`) unrolls a fixed
  ``len(table)``-trip loop over the rows at staging time and emits
  straight-line, branch-free vector arithmetic over the Q queue slots —
  an interpreter while tracing, a branchless dataflow program at run
  time.
* **Runtime operand** (PR 10): the table is packed into a dense int32
  array (:func:`pack_program`, padded to a :func:`table_bucket` length
  so only the BUCKET — never the content — reaches the compile key) and
  interpreted by :func:`evaluate_table`, a branchless table-driven VM:
  each row gathers its operands dynamically and selects among every
  opcode's candidate result. One compiled executable then evaluates ANY
  program of that bucket — and ``jax.vmap`` over stacked packed tables
  evaluates hundreds of candidate policies per dispatch
  (``emulator.run_policies``). Bit-identical to the staged path by
  construction: identical int32 candidate arithmetic, exact selects.

Every instruction is O(Q) int32 work either way, so a policy adds
O(L * Q) per scheduling slot and preserves the engine's O(Q)+O(1)
per-slot invariant (L = program length / bucket, a small constant; the
runtime VM pays a constant-factor premium — all opcode candidates per
row — which the policy axis amortizes across the batch).

A program produces a per-slot ``score`` (int32, lower = served first)
and an optional ``boost`` mask (nonzero = preferred class). Selection is
the same two-level argmin the hard-coded scheduler used: the oldest-
score request among boosted visible slots if any, else among all visible
slots — which is what makes the built-in :func:`frfcfs_program` /
:func:`fcfs_program` *bit-identical* to the legacy ``sys.scheduler``
string flag (pinned in tests/test_smcprog.py).

Cost model
----------

The SMC is slow — that slowness is the very thing time scaling hides, so
it must be modeled, not ignored. A program's decision cost is derived
from its length: ``smc_cycles() = base_cycles + cycles_per_op * len``
(override with ``smc_cycles_override`` to pin a calibrated number).
``SystemConfig.with_policy(prog)`` folds that cost into
``smc_cycles_per_decision``, so a ``ts`` vs ``nots`` sweep of one
policy grid is a first-class experiment: ``ts`` results are invariant
to program length (the paper's claim), ``nots`` results degrade with it
(the inaccuracy the paper quantifies). Attaching a program with plain
``dataclasses.replace(sys, policy=prog)`` keeps the config's existing
cost — that is what the bit-identity tests use.

Quickstart — a custom policy in ~20 lines::

    from repro.core.smcprog import PolicyBuilder
    from repro.core.timescale import JETSON_NANO
    from repro.core.emulator import run

    b = PolicyBuilder()
    age = b.score_age()            # arrival time, lower = older
    hit = b.score_row_hit()        # 1 where the bank's open row matches
    busy = b.mask_bank_busy()      # 1 where the request's bank is busy
    # serve oldest, but penalize requests on busy banks by 64 cycles,
    # and prefer row hits whenever any are visible
    score = b.add(age, b.mul(busy, b.const(64)))
    prog = b.build(score=score, boost=hit, name="hit-first-idle-banks")

    sysc = JETSON_NANO.with_policy(prog)     # cost derived from length
    out = run(trace, sysc, "ts")
    print(prog.smc_cycles(), prog.digest, prog.describe())

Quickstart — 256 candidate policies, ONE compiled dispatch (the
runtime-operand axis; table content is data, only the length bucket
rides the compile key), then a short autotune run::

    from repro.core import emulator
    from repro.core.policysearch import random_program, search
    import numpy as np

    rng = np.random.RandomState(0)
    progs = [random_program(rng, name=f"cand{i}") for i in range(256)]
    recs = emulator.run_policies(trace, JETSON_NANO, progs, mode="ts")
    best = min(recs, key=lambda r: float(r["avg_load_latency_cycles"]))

    res = search(trace, JETSON_NANO, generations=5, population=16)
    print(res.summary())           # tuned-vs-baseline table
    print(res.best.describe())     # the winning schedule, one dispatch
                                   # per generation under the hood

(Full walkthrough: ``examples/policy_lab.py``.)

Sweeping a grid of policies goes through
:meth:`repro.core.campaign.Campaign.add_policy_grid` — by default one
vmapped policy-axis dispatch per (trace, mode) with programs sharing a
table bucket; ``policy_axis=False`` selects the staged per-program
path. Built-ins: :func:`frfcfs_program`,
:func:`fcfs_program`, :func:`bank_round_robin_program`,
:func:`open_page_program`, :func:`closed_page_program`,
:func:`write_drain_program` (see :func:`builtin_programs`).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# Same sentinel value as repro.core.emulator.BIG — but a plain Python
# int: a module-level jnp constant would initialize the JAX backend at
# import time, and this module is imported by the otherwise jax-free
# config layer (timescale.py), which must stay importable before
# jax_compat.enable_fast_cpu_scan().
BIG = 2 ** 30

# ---------------------------------------------------------------------------
# Opcodes. Loads read one named input vector of the scheduling environment
# (length Q, int32); ALU ops combine previously-computed values. Booleans
# are int32 0/1. All arithmetic wraps in int32 (document, don't guard).
# ---------------------------------------------------------------------------

OP_CONST = 0           # imm -> broadcast constant
# environment loads
OP_AGE = 1             # request arrival time (proc cycles; lower = older)
OP_AGE_REL = 2         # age minus the oldest *visible* age (small ints)
OP_ROW_HIT = 3         # 1 where the bank's open row matches the request row
OP_BANK = 4            # request bank index
OP_ROW = 5             # request row index
OP_IS_WRITE = 6        # 1 where the request is a WRITE
OP_BANK_BUSY = 7       # 1 where the request's bank is busy at the DRAM frontier
OP_RR_DIST = 8         # cyclic bank distance from the last served bank
OP_QSLOT = 9           # hardware-queue slot index 0..Q-1
OP_WRITE_PRESSURE = 10  # count of visible writes, broadcast to all slots
OP_HAMMER_CT = 11      # request bank's aggressor ACT counter (faults model)
OP_PARA_RAND = 12      # per-slot uniform 16-bit draw in [0, 65536) (PARA)
# ALU
OP_ADD = 16
OP_SUB = 17
OP_MUL = 18
OP_MIN = 19
OP_MAX = 20
OP_AND = 21            # bitwise (use on 0/1 masks)
OP_OR = 22
OP_NOT = 23            # (a == 0) -> 0/1
OP_EQ = 24
OP_LT = 25
OP_GE = 26
OP_SELECT = 27         # a != 0 ? b : imm-indexed?  (c, a, b) -> see builder

_LOAD_NAMES = {
    OP_AGE: "age", OP_AGE_REL: "age_rel", OP_ROW_HIT: "row_hit",
    OP_BANK: "bank", OP_ROW: "row", OP_IS_WRITE: "is_write",
    OP_BANK_BUSY: "bank_busy", OP_RR_DIST: "rr_dist", OP_QSLOT: "qslot",
    OP_WRITE_PRESSURE: "write_pressure",
    OP_HAMMER_CT: "hammer_ct", OP_PARA_RAND: "para_rand",
}
_OP_NAMES = {v: k for k, v in globals().items() if k.startswith("OP_")}
_UNARY = {OP_NOT}
_BINARY = {OP_ADD, OP_SUB, OP_MUL, OP_MIN, OP_MAX, OP_AND, OP_OR,
           OP_EQ, OP_LT, OP_GE}
_INT32_MIN, _INT32_MAX = -(2 ** 31), 2 ** 31 - 1


@dataclasses.dataclass(frozen=True)
class Reg:
    """Handle to one SSA value of one builder. Opaque to callers."""
    idx: int
    owner: int = dataclasses.field(repr=False, compare=False, default=0)


@dataclasses.dataclass(frozen=True)
class PolicyProgram:
    """An assembled policy: a dense int32 instruction table in SSA form.

    ``table`` rows are ``(opcode, a, b, imm)``; row *i* defines value
    *i*, operands ``a``/``b`` reference earlier rows. ``score_reg`` /
    ``boost_reg`` name the output values (``boost_reg == -1`` = no
    boost class). Equality and hashing are by *semantic* content —
    ``name`` and the cost-model fields are excluded — so the emulator
    compile cache and Campaign grouping are content-addressed (same
    table = one executable).
    """
    table: Tuple[Tuple[int, int, int, int], ...]
    score_reg: int
    boost_reg: int = -1
    # optional mitigation output: nonzero on the SERVED slot triggers a
    # targeted neighbor refresh on its bank (RowHammer defense) — the
    # engine charges dram.neighbor_refresh_ticks and resets the bank's
    # aggressor counter. -1 = the policy never mitigates (all pre-fault
    # programs), which keeps select_slot's trace byte-identical.
    mitigate_reg: int = -1
    # cost-model fields never enter the emulation semantics (with_policy
    # copies the cost onto SystemConfig.smc_cycles_per_decision, which
    # IS compared), so like `name` they are excluded from eq/hash —
    # same-table programs share one compile-key group
    base_cycles: int = dataclasses.field(default=300, compare=False)
    cycles_per_op: int = dataclasses.field(default=25, compare=False)
    smc_cycles_override: Optional[int] = dataclasses.field(
        default=None, compare=False)
    name: str = dataclasses.field(default="policy", compare=False)

    @property
    def n_ops(self) -> int:
        return len(self.table)

    def smc_cycles(self) -> int:
        """SMC cycles per scheduling decision — the program-length cost
        model (``base + per_op * len``), or the calibrated override."""
        if self.smc_cycles_override is not None:
            return int(self.smc_cycles_override)
        return int(self.base_cycles + self.cycles_per_op * self.n_ops)

    @property
    def digest(self) -> str:
        """Content digest (table + outputs); what the compile key sees.
        mitigate_reg joins the repr only when set, so every pre-fault
        program keeps its historical digest."""
        sem = (self.table, self.score_reg, self.boost_reg)
        if self.mitigate_reg >= 0:
            sem = sem + (self.mitigate_reg,)
        return hashlib.sha1(repr(sem).encode()).hexdigest()[:12]

    def uses(self, opcode: int) -> bool:
        return any(row[0] == opcode for row in self.table)

    def validate(self) -> "PolicyProgram":
        """Structural check; errors carry the table row index AND the
        decoded op name (``row 3 (op_add): ...``) so search-generated
        invalid programs point straight at the offending instruction."""
        n = len(self.table)
        if not 0 <= self.score_reg < n:
            raise ValueError(f"score_reg {self.score_reg} out of range "
                             f"for a {n}-row table")
        if not -1 <= self.boost_reg < n:
            raise ValueError(f"boost_reg {self.boost_reg} out of range "
                             f"for a {n}-row table")
        if not -1 <= self.mitigate_reg < n:
            raise ValueError(f"mitigate_reg {self.mitigate_reg} out of "
                             f"range for a {n}-row table")
        for i, (op, a, b, imm) in enumerate(self.table):
            nm = _OP_NAMES.get(op, f"op{op}").lower()
            if op != OP_CONST and op not in _LOAD_NAMES \
                    and op not in _UNARY and op not in _BINARY \
                    and op != OP_SELECT:
                raise ValueError(f"row {i}: unknown opcode {op}")
            refs = (() if op == OP_CONST or op in _LOAD_NAMES
                    else (a,) if op in _UNARY
                    else (a, b) if op in _BINARY else (a, b, imm))
            for r in refs:
                if not 0 <= r < i:
                    raise ValueError(
                        f"row {i} ({nm}): operand {r} is not an earlier "
                        f"value")
            if op == OP_CONST and not _INT32_MIN <= imm <= _INT32_MAX:
                raise ValueError(f"row {i} ({nm}): imm {imm} not int32")
        return self

    def describe(self) -> str:
        """Human-readable disassembly (one line per instruction)."""
        lines = [f"{self.name}: {self.n_ops} ops, "
                 f"{self.smc_cycles()} smc-cycles/decision, "
                 f"digest {self.digest}"]
        for i, (op, a, b, imm) in enumerate(self.table):
            nm = _OP_NAMES.get(op, f"op{op}").lower()[3:]
            if op == OP_CONST:
                arg = str(imm)
            elif op in _LOAD_NAMES:
                arg = ""
            elif op in _UNARY:
                arg = f"v{a}"
            elif op == OP_SELECT:
                arg = f"v{a} ? v{b} : v{imm}"
            else:
                arg = f"v{a}, v{b}"
            out = []
            if i == self.score_reg:
                out.append("score")
            if i == self.boost_reg:
                out.append("boost")
            if i == self.mitigate_reg:
                out.append("mitigate")
            tag = ("   -> " + "+".join(out)) if out else ""
            arg = f" {arg}" if arg else ""
            lines.append(f"  v{i} = {nm}{arg}{tag}")
        return "\n".join(lines)


class PolicyBuilder:
    """Author a :class:`PolicyProgram` op by op (SSA; each method
    returns a :class:`Reg` naming its result). See the module docstring
    for a complete example."""

    def __init__(self) -> None:
        self._rows: list = []

    def _emit(self, op: int, a: int = 0, b: int = 0, imm: int = 0) -> Reg:
        self._rows.append((op, a, b, imm))
        return Reg(len(self._rows) - 1, id(self))

    def _r(self, reg: Reg) -> int:
        if not isinstance(reg, Reg) or reg.owner != id(self):
            raise ValueError(f"{reg!r} is not a register of this builder")
        return reg.idx

    # ---- environment loads (the semantic ops of the issue) ----
    def score_age(self) -> Reg:
        """Arrival time in proc cycles: ``argmin`` over it = FCFS."""
        return self._emit(OP_AGE)

    def age_rel(self) -> Reg:
        """Age relative to the oldest visible request (small values —
        safe to combine with multiplied terms without int32 overflow)."""
        return self._emit(OP_AGE_REL)

    def score_row_hit(self) -> Reg:
        """1 where the request hits its bank's open row, else 0."""
        return self._emit(OP_ROW_HIT)

    def bank(self) -> Reg:
        return self._emit(OP_BANK)

    def row(self) -> Reg:
        return self._emit(OP_ROW)

    def is_write(self) -> Reg:
        return self._emit(OP_IS_WRITE)

    def mask_bank_busy(self) -> Reg:
        """1 where the request's bank is still busy at the DRAM
        frontier (its ready tick lies in the future), else 0."""
        return self._emit(OP_BANK_BUSY)

    def rr_distance(self) -> Reg:
        """Cyclic distance from the last served bank: 0 = the next bank
        round-robin order would pick, n_banks-1 = the bank just served."""
        return self._emit(OP_RR_DIST)

    def qslot(self) -> Reg:
        return self._emit(OP_QSLOT)

    def write_pressure(self) -> Reg:
        """Number of visible writes, broadcast to every slot."""
        return self._emit(OP_WRITE_PRESSURE)

    def hammer_count(self) -> Reg:
        """The request bank's aggressor ACT counter (see
        repro.core.faults). All-zero when no FaultModel is attached, so
        counter-based TRR degrades to a no-op on a perfect memory."""
        return self._emit(OP_HAMMER_CT)

    def para_rand(self) -> Reg:
        """Per-slot uniform draw in [0, 65536), deterministically keyed
        on (fault seed, bank, row, decision time) — compare against a
        16-bit fixed-point constant for a PARA coin flip."""
        return self._emit(OP_PARA_RAND)

    def prefer_writes_drain(self, threshold: int = 2) -> Reg:
        """Write-drain mask: 1 on write requests while at least
        ``threshold`` writes are visible (batch writes to amortize bus
        turnarounds), else 0. A macro over 4 IR instructions."""
        wp = self.write_pressure()
        thr = self.const(threshold)
        drain = self.ge(wp, thr)
        return self.and_(self.is_write(), drain)

    # ---- ALU ----
    def const(self, value: int) -> Reg:
        return self._emit(OP_CONST, imm=int(value))

    def add(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_ADD, self._r(a), self._r(b))

    def sub(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_SUB, self._r(a), self._r(b))

    def mul(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_MUL, self._r(a), self._r(b))

    def min_(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_MIN, self._r(a), self._r(b))

    def max_(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_MAX, self._r(a), self._r(b))

    def and_(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_AND, self._r(a), self._r(b))

    def or_(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_OR, self._r(a), self._r(b))

    def not_(self, a: Reg) -> Reg:
        return self._emit(OP_NOT, self._r(a))

    def eq(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_EQ, self._r(a), self._r(b))

    def lt(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_LT, self._r(a), self._r(b))

    def ge(self, a: Reg, b: Reg) -> Reg:
        return self._emit(OP_GE, self._r(a), self._r(b))

    def select(self, cond: Reg, a: Reg, b: Reg) -> Reg:
        """``cond != 0 ? a : b`` elementwise."""
        return self._emit(OP_SELECT, self._r(cond), self._r(a),
                          imm=self._r(b))

    def build(self, score: Reg, boost: Optional[Reg] = None,
              mitigate: Optional[Reg] = None,
              name: str = "policy", base_cycles: int = 300,
              cycles_per_op: int = 25,
              smc_cycles: Optional[int] = None) -> PolicyProgram:
        """Assemble. ``score`` is minimized among visible requests;
        ``boost`` (optional 0/1 mask) marks a preferred class served
        first whenever any member is visible; ``mitigate`` (optional 0/1
        mask) triggers a neighbor refresh when the served slot has it
        set. ``smc_cycles`` pins the decision cost instead of deriving
        it from program length."""
        return PolicyProgram(
            table=tuple(self._rows), score_reg=self._r(score),
            boost_reg=-1 if boost is None else self._r(boost),
            mitigate_reg=-1 if mitigate is None else self._r(mitigate),
            base_cycles=base_cycles, cycles_per_op=cycles_per_op,
            smc_cycles_override=smc_cycles, name=name).validate()


# ---------------------------------------------------------------------------
# Evaluator: staged inside the emulator's scan slot body. ``env`` maps
# load names to zero-arg thunks returning [Q] int32 vectors; thunks are
# evaluated at most once, and only for the loads the program references.
# ---------------------------------------------------------------------------


def evaluate(prog: PolicyProgram, env: Dict):
    """Run ``prog`` over the scheduling environment. Returns
    ``(score, boost, mitigate)`` — [Q] int32 vectors (boost is all-zero
    when the program declared no boost register; mitigate is None when
    no mitigate register, so legacy programs stage zero extra ops)."""
    cache: Dict[str, object] = {}

    def load(nm):
        if nm not in cache:
            cache[nm] = jnp.asarray(env[nm]()).astype(jnp.int32)
        return cache[nm]

    vals = []
    for op, a, b, imm in prog.table:
        if op == OP_CONST:
            v = jnp.full_like(load("qslot"), jnp.int32(imm))
        elif op in _LOAD_NAMES:
            v = load(_LOAD_NAMES[op])
        elif op == OP_ADD:
            v = vals[a] + vals[b]
        elif op == OP_SUB:
            v = vals[a] - vals[b]
        elif op == OP_MUL:
            v = vals[a] * vals[b]
        elif op == OP_MIN:
            v = jnp.minimum(vals[a], vals[b])
        elif op == OP_MAX:
            v = jnp.maximum(vals[a], vals[b])
        elif op == OP_AND:
            v = vals[a] & vals[b]
        elif op == OP_OR:
            v = vals[a] | vals[b]
        elif op == OP_NOT:
            v = (vals[a] == 0).astype(jnp.int32)
        elif op == OP_EQ:
            v = (vals[a] == vals[b]).astype(jnp.int32)
        elif op == OP_LT:
            v = (vals[a] < vals[b]).astype(jnp.int32)
        elif op == OP_GE:
            v = (vals[a] >= vals[b]).astype(jnp.int32)
        elif op == OP_SELECT:
            v = jnp.where(vals[a] != 0, vals[b], vals[imm])
        else:  # pragma: no cover - validate() rejects these
            raise ValueError(f"unknown opcode {op}")
        vals.append(v.astype(jnp.int32))
    score = vals[prog.score_reg]
    boost = (vals[prog.boost_reg] if prog.boost_reg >= 0
             else jnp.zeros_like(score))
    mit = vals[prog.mitigate_reg] if prog.mitigate_reg >= 0 else None
    return score, boost, mit


def select_slot(prog: PolicyProgram, env: Dict, visible):
    """Pick the queue slot to serve: two-level argmin over the program's
    score — boosted visible requests first (when any), else all visible.
    Identical selection structure to the legacy hard-coded scheduler,
    which is what makes :func:`frfcfs_program` / :func:`fcfs_program`
    bit-identical to the ``sys.scheduler`` string path. Scores are
    clamped to ``BIG - 1`` so a user program can never out-score the
    invisible-slot sentinel and redirect the argmin to a garbage slot.

    Returns ``(qslot, mitigate)``: the selected slot, and the selected
    slot's mitigate flag (scalar bool) or None for legacy programs —
    None keeps the staged trace byte-identical to pre-fault builds."""
    score, boost, mit = evaluate(prog, env)
    score = jnp.minimum(score, BIG - 1)
    key_all = jnp.where(visible, score, BIG)
    boost_on = visible & (boost != 0)
    key_boost = jnp.where(boost_on, score, BIG)
    slot_boost = jnp.argmin(key_boost).astype(jnp.int32)
    slot_all = jnp.argmin(key_all).astype(jnp.int32)
    qslot = jnp.where(jnp.any(boost_on), slot_boost, slot_all)
    return qslot, (None if mit is None else mit[qslot] != 0)


# ---------------------------------------------------------------------------
# Runtime-operand path (PR 10): pack a program into a dense int32 array
# and interpret it with a table-driven VM. Only the PADDED LENGTH of the
# table (its bucket) is a traced-shape property; the content is a plain
# runtime operand, so one compiled emulator evaluates any program of a
# bucket — and a vmap over stacked tables evaluates a whole policy grid.
# ---------------------------------------------------------------------------

# Smallest bucket: all built-ins fit in 8 rows, and a floor keeps the
# number of distinct buckets (== distinct compile keys) tiny.
TABLE_BUCKET_FLOOR = 8

# Environment loads in opcode order — row `op - OP_AGE` of the stacked
# env matrix. Contiguity of OP_AGE..OP_PARA_RAND is load-bearing here.
_ENV_ORDER = tuple(_LOAD_NAMES[op] for op in range(OP_AGE, OP_PARA_RAND + 1))
N_LOADS = len(_ENV_ORDER)


def table_bucket(n_ops: int) -> int:
    """Padded table length for an ``n_ops``-row program: the next power
    of two, floored at :data:`TABLE_BUCKET_FLOOR`. The bucket — never
    the content — rides the compile key."""
    if n_ops < 1:
        raise ValueError(f"n_ops must be >= 1, got {n_ops}")
    b = TABLE_BUCKET_FLOOR
    while b < n_ops:
        b *= 2
    return b


def pack_program(prog: PolicyProgram,
                 bucket: Optional[int] = None) -> np.ndarray:
    """Pack a validated program into the runtime-operand layout: an
    int32 ``[bucket + 1, 4]`` array whose row 0 is the header
    ``(n_ops, score_reg, boost_reg, mitigate_reg)`` and rows 1.. are the
    instruction table padded with ``(OP_CONST, 0, 0, 0)`` no-ops (they
    execute — producing zeros no live row references — so the VM needs
    no length gate)."""
    prog.validate()
    lb = table_bucket(prog.n_ops) if bucket is None else int(bucket)
    if prog.n_ops > lb:
        raise ValueError(
            f"program {prog.name!r} has {prog.n_ops} ops; bucket {lb} "
            f"is too small (needs {table_bucket(prog.n_ops)})")
    out = np.zeros((lb + 1, 4), np.int32)
    out[0] = (prog.n_ops, prog.score_reg, prog.boost_reg,
              prog.mitigate_reg)
    for i, row in enumerate(prog.table):
        out[i + 1] = row
    return out


def pack_stack(progs: Sequence[PolicyProgram],
               bucket: Optional[int] = None) -> np.ndarray:
    """Stack packed programs into one ``[P, bucket + 1, 4]`` int32 array
    — the policy-axis operand. ``bucket`` defaults to the max bucket
    over the programs (callers that must NOT silently merge buckets,
    e.g. ``Campaign.add_policy_grid``, group first and pass it)."""
    if not progs:
        raise ValueError("pack_stack needs at least one program")
    lb = (max(table_bucket(p.n_ops) for p in progs)
          if bucket is None else int(bucket))
    return np.stack([pack_program(p, lb) for p in progs])


def eval_table_rows(rows, envm):
    """The table-driven VM core: interpret ``rows`` ([L, 4] int32
    instructions) over ``envm`` ([N_LOADS, Q] int32 stacked environment)
    and return all SSA values as [L, Q] int32. Branchless — every row
    computes every opcode's candidate and selects by opcode — so it
    traces to a fixed dataflow program regardless of table content.
    Candidate arithmetic matches :func:`evaluate` op for op (int32
    wraparound included), which is what makes the runtime path
    bit-identical to the staged path. Shared verbatim by
    :func:`evaluate_table` and the ``kernels/policy_vm`` Pallas kernel
    (single source of semantics)."""
    L = rows.shape[0]
    q = envm.shape[1]

    def body(i, vals):
        op = rows[i, 0]
        a = jnp.clip(rows[i, 1], 0, L - 1)
        b = jnp.clip(rows[i, 2], 0, L - 1)
        imm = rows[i, 3]
        va = vals[a]
        vb = vals[b]
        vc = vals[jnp.clip(imm, 0, L - 1)]
        # OP_CONST is the default arm (also the padding no-op).
        v = jnp.zeros((q,), jnp.int32) + imm
        is_load = (op >= OP_AGE) & (op <= OP_PARA_RAND)
        v = jnp.where(is_load,
                      envm[jnp.clip(op - OP_AGE, 0, N_LOADS - 1)], v)
        for code, cand in (
                (OP_ADD, va + vb),
                (OP_SUB, va - vb),
                (OP_MUL, va * vb),
                (OP_MIN, jnp.minimum(va, vb)),
                (OP_MAX, jnp.maximum(va, vb)),
                (OP_AND, va & vb),
                (OP_OR, va | vb),
                (OP_NOT, (va == 0).astype(jnp.int32)),
                (OP_EQ, (va == vb).astype(jnp.int32)),
                (OP_LT, (va < vb).astype(jnp.int32)),
                (OP_GE, (va >= vb).astype(jnp.int32)),
                (OP_SELECT, jnp.where(va != 0, vb, vc)),
        ):
            v = jnp.where(op == code, cand, v)
        return vals.at[i].set(v.astype(jnp.int32))

    return jax.lax.fori_loop(0, L, body, jnp.zeros((L, q), jnp.int32))


def evaluate_table(table, env: Dict):
    """Runtime-operand counterpart of :func:`evaluate`: run a packed
    ``[L + 1, 4]`` table (header + rows, :func:`pack_program` layout)
    over the scheduling environment. Returns ``(score, boost, mitigate)``
    [Q] int32 vectors; unlike the staged path, mitigate is always a
    vector (all-zero when the program declared none) — the table content
    is not known at trace time, and an always-False mitigate flag is
    numerically identical to None in ``faults.apply_slot``. Evaluates
    every environment thunk (the stacked env matrix is shared across the
    whole policy axis, so the cost amortizes)."""
    table = jnp.asarray(table, jnp.int32)
    hdr = table[0]
    rows = table[1:]
    lb = rows.shape[0]
    envm = jnp.stack([jnp.asarray(env[nm]()).astype(jnp.int32)
                      for nm in _ENV_ORDER])
    vals = eval_table_rows(rows, envm)
    score = vals[jnp.clip(hdr[1], 0, lb - 1)]
    zero = jnp.zeros_like(score)
    boost = jnp.where(hdr[2] >= 0, vals[jnp.clip(hdr[2], 0, lb - 1)], zero)
    mit = jnp.where(hdr[3] >= 0, vals[jnp.clip(hdr[3], 0, lb - 1)], zero)
    return score, boost, mit


def select_slot_table(table, env: Dict, visible):
    """Runtime-operand counterpart of :func:`select_slot`: identical
    two-level argmin (clamp, boosted-first, else all-visible). Returns
    ``(qslot, mitigate_flag)`` where the flag is a traced scalar bool —
    always present, always False for programs without a mitigate
    register (bit-identical to the staged path's None, see
    ``faults.apply_slot``)."""
    score, boost, mit = evaluate_table(table, env)
    score = jnp.minimum(score, BIG - 1)
    key_all = jnp.where(visible, score, BIG)
    boost_on = visible & (boost != 0)
    key_boost = jnp.where(boost_on, score, BIG)
    slot_boost = jnp.argmin(key_boost).astype(jnp.int32)
    slot_all = jnp.argmin(key_all).astype(jnp.int32)
    qslot = jnp.where(jnp.any(boost_on), slot_boost, slot_all)
    return qslot, mit[qslot] != 0


# ---------------------------------------------------------------------------
# Built-in programs.
# ---------------------------------------------------------------------------


def frfcfs_program() -> PolicyProgram:
    """FR-FCFS: oldest-first, row hits first. Bit-identical to the
    legacy ``scheduler='frfcfs'`` flag (tests/test_smcprog.py)."""
    b = PolicyBuilder()
    return b.build(score=b.score_age(), boost=b.score_row_hit(),
                   name="frfcfs")


def fcfs_program() -> PolicyProgram:
    """FCFS: strictly oldest-first. Bit-identical to the legacy
    ``scheduler='fcfs'`` flag."""
    b = PolicyBuilder()
    return b.build(score=b.score_age(), name="fcfs")


def bank_round_robin_program() -> PolicyProgram:
    """Cycle banks after the last served bank; age (relative, so the
    scaled term can't overflow int32) breaks ties within a bank."""
    b = PolicyBuilder()
    rr = b.rr_distance()
    age = b.min_(b.age_rel(), b.const((1 << 20) - 1))
    score = b.add(b.mul(rr, b.const(1 << 20)), age)
    return b.build(score=score, name="bank-rr")


def open_page_program() -> PolicyProgram:
    """Open-page: like FR-FCFS but only boosts hits on banks that are
    already idle — a hit on a busy bank waits its turn by age."""
    b = PolicyBuilder()
    hit_idle = b.and_(b.score_row_hit(), b.not_(b.mask_bank_busy()))
    return b.build(score=b.score_age(), boost=hit_idle, name="open-page")


def closed_page_program() -> PolicyProgram:
    """Closed-page: no row-hit preference — drain conflicts early by
    boosting row misses. (The bank state machine still keeps rows open;
    this isolates the *scheduling* component of a closed-page MC.)"""
    b = PolicyBuilder()
    return b.build(score=b.score_age(), boost=b.not_(b.score_row_hit()),
                   name="closed-page")


def write_drain_program(threshold: int = 2) -> PolicyProgram:
    """Age-ordered with write-drain mode: once ``threshold`` writes are
    visible, writes are served first until the backlog drops."""
    b = PolicyBuilder()
    return b.build(score=b.score_age(),
                   boost=b.prefer_writes_drain(threshold),
                   name=f"write-drain{threshold}")


def builtin_programs() -> Dict[str, PolicyProgram]:
    """All built-ins keyed by name — the default policy-sweep grid."""
    progs = [frfcfs_program(), fcfs_program(), bank_round_robin_program(),
             open_page_program(), closed_page_program(),
             write_drain_program()]
    return {p.name: p for p in progs}


# ---------------------------------------------------------------------------
# RowHammer mitigation policies: FR-FCFS scheduling plus a mitigate
# output. Kept OUT of builtin_programs() — the default policy-sweep
# grid (and its tests) is mitigation-free; sweeps come in through
# techniques.RowHammerMitigationStudy / mitigation_programs().
# ---------------------------------------------------------------------------


def para_program(p_fp: int = 655) -> PolicyProgram:
    """PARA: on every row activation (a served row *miss*), refresh the
    neighbors with probability ``p_fp``/65536 (default ~1%). Stateless —
    no counters — which is PARA's selling point; the cost is paying the
    refresh tax on well-behaved traffic too."""
    if not 0 <= p_fp <= 65536:
        raise ValueError(f"p_fp is 16-bit fixed point, got {p_fp}")
    b = PolicyBuilder()
    hit = b.score_row_hit()
    coin = b.lt(b.para_rand(), b.const(p_fp))
    return b.build(score=b.score_age(), boost=hit,
                   mitigate=b.and_(coin, b.not_(hit)),
                   name=f"para{p_fp}")


def trr_program(trr_threshold: int = 512) -> PolicyProgram:
    """Counter-based TRR: refresh the neighbors when the request bank's
    aggressor ACT counter reaches ``trr_threshold``. Deterministic and
    cheap when traffic is benign; choose the threshold below the chip's
    hammer threshold or the mitigation fires too late."""
    if trr_threshold < 1:
        raise ValueError(f"trr_threshold must be >= 1, got {trr_threshold}")
    b = PolicyBuilder()
    return b.build(score=b.score_age(), boost=b.score_row_hit(),
                   mitigate=b.ge(b.hammer_count(), b.const(trr_threshold)),
                   name=f"trr{trr_threshold}")


def mitigation_programs(para_fp: int = 655,
                        trr_threshold: int = 512) -> Dict[str, PolicyProgram]:
    """The RowHammer-mitigation sweep arms, keyed by name: unmitigated
    FR-FCFS baseline + PARA + counter-based TRR."""
    progs = [frfcfs_program(), para_program(para_fp),
             trr_program(trr_threshold)]
    return {p.name: p for p in progs}
