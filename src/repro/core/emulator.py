"""The EasyDRAM engine: trace-driven, multi-domain, time-scaled emulation.

One fused ``lax.scan`` implements the whole request lifetime of Fig. 6:
processor issue (bounded-window in-order front end) -> hardware request
buffer -> SMC critical mode (visibility cutoff on the time-scaling
counter) -> scheduling decision -> DRAM-Bender-style command-batch
execution on the bank state machine -> response tagged with its consume
cycle -> counter advance.

The scheduling decision is software-defined: when ``sys.policy`` is a
:class:`repro.core.smcprog.PolicyProgram`, its instruction table is
interpreted inside the slot body by the branchless policy VM
(O(program-length * Q) extra work per slot, preserving the O(Q)
invariant below); otherwise the legacy hard-coded ``sys.scheduler``
FR-FCFS/FCFS branch runs. The built-in FR-FCFS/FCFS programs are
bit-identical to the legacy flag (tests/test_smcprog.py). The program's
content rides in the compile key through ``SystemConfig`` (programs
hash by table content), so policy sweeps group per program in
:func:`run_many` / ``Campaign``.

Each scan step performs one SMC scheduling slot (serve one visible
request, or an idle hop to the next arrival). All arithmetic is exact
int32 (DRAM ticks / processor cycles, fixed-point 1/4096 conversion);
results are bit-reproducible, which is what lets the Sec. 6 validation
assert exact invariance of time-scaled results to FPGA-side clocks.

Per-slot cost model (the O(Q) invariant)
----------------------------------------

The slot body does O(Q) + O(1) work, where Q = max(window, 2) is the
hardware-queue depth — NOT O(N) in the trace length: every state update
is a predicated point-scatter ``arr.at[i].set(where(pred, new, arr[i]))``
(a self-write when disabled), which XLA keeps in place on the scan carry,
and every read is a point gather. A whole trace therefore costs
O(slots * Q), linear in the trace, where the slot count is the exact
per-batch budget below. The pre-optimization engine (kept verbatim as
:func:`run_ref` / ``_run_core_ref`` for A/B tests and benchmarks) instead
paid full-length predicated selects per slot — O(bucket) work per slot,
O(bucket^2) per trace.

Slot budget
-----------

A real (non-NOP) request needs at most 2 slots (an idle hop that parks
the MC counter at its arrival, then its serve); NOPs (mid-trace or
trailing padding) resolve in the issue frontier at 4 per slot and never
enter the queue. (The idle hop is skipped outright while the hardware
queue is empty — e.g. during a mid-trace NOP run that drains it — so
the MC counter stays parked instead of saturating to BIG-1; the
pre-PR-4 engines saturated there and poisoned every later response.
Both engines carry the fix identically.) For a batch
group padded to ``bucket`` whose largest trace has R real requests, the
scan therefore runs

    slots = 2 * Rq + ceil((bucket - Rq) / 4) + 4,   Rq = R rounded up to
                                                    a bucket/4 granule

slots instead of the previous uniform ``2 * bucket + 4``. Rounding R up
to a coarse granule (and folding ``slots`` into the compile key) keeps
nearby batch shapes on one cached executable; the extra slots are no-ops
(the scan is idempotent once every request is served), so results are
bit-identical for any budget at or above the exact one — asserted by the
property tests against the reference engine.

Entry points:

* :func:`run` — one trace, one config, one mode. A thin wrapper over a
  batch of one.
* :func:`run_many` — a batched campaign step: pads every trace to one
  length bucket, stacks them on a leading axis, and ``jax.vmap``s the
  scan over that axis (optionally over per-trace Bloom filters too), so
  a whole sweep shares ONE compile and ONE device dispatch. Compiled
  executables are cached at module level keyed on
  ``(bucket, slots, batch, sys, mode, bloom-shape)`` — repeated sweeps
  never recompile (see :func:`cache_stats`; the cache is LRU-bounded,
  :func:`set_cache_capacity`). With more than one local device the
  padded batch axis is ``shard_map``-sharded across them
  (:func:`set_sharding`), and multi-group calls execute overlapped
  through ``repro.core.executor`` (``serial=True`` forces the in-order
  loop). Trace buffers are donated to the executable (they are rebuilt
  from host arrays each call). Results are bit-identical to per-trace
  :func:`run` in every combination. For grids that also vary
  ``SystemConfig`` / technique, drive this through
  :class:`repro.core.campaign.Campaign`. A fresh process can skip the
  cold compiles entirely via
  :func:`repro.utils.jax_compat.enable_persistent_compile_cache`.
* :func:`run_ref` / :func:`run_ref_many` — the pre-optimization
  O(bucket)-per-slot engine, kept only to pin bit-exactness and to
  measure the steady-state speedup in ``benchmarks/run.py --section
  sim_speed``.

Note on XLA:CPU: the thunk runtime (jaxlib >= 0.4.32 default) executes
the tiny per-slot ops of this scan through its intra-op thread pool and
defeats in-place carry updates — a ~30x steady-state slowdown. Benchmark
and example entry points call
:func:`repro.utils.jax_compat.enable_fast_cpu_scan` before the backend
initializes to select the legacy inline runtime.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import warnings
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dram, executor, smcprog
from repro.core.bloom import bloom_probe_jnp
from repro.core.dram import NOP, WRITE
from repro.core.timescale import SystemConfig

BIG = jnp.int32(2 ** 30)
FP = 4096  # fixed-point denominator for tick<->cycle conversion

# donation is best-effort by design (see _batched_fn); the per-call
# catch_warnings there is not thread-safe (process-global filter state),
# so overlapped group execution needs the filter installed up front too
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

def _mul_div(a, num, den):
    """Exact a * num // den without int32 overflow (num, den ~ 1e3..1e4)."""
    q = a // den
    r = a - q * den
    return q * num + (r * num) // den


def _policy_env(q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                bank_ready, dram_now, last_bank, n_banks: int, Q: int):
    """Scheduling environment for the policy VM: one thunk per load op,
    each returning a [Q] int32 vector. :func:`smcprog.evaluate` calls
    only the thunks the program references (and each at most once), so
    an FR-FCFS program pays for exactly the two vectors the hard-coded
    scheduler already computed. Shared by both engine cores so the
    policy semantics cannot drift between them."""
    is_write = lambda: (kindj[qidx] == WRITE).astype(jnp.int32)  # noqa: E731
    return {
        "age": lambda: q_t,
        "age_rel": lambda: q_t - jnp.min(jnp.where(visible, q_t, BIG)),
        "row_hit": lambda: hit_now.astype(jnp.int32),
        "bank": lambda: q_bank,
        "row": lambda: q_row,
        "is_write": is_write,
        "bank_busy": lambda: (bank_ready[q_bank] > dram_now).astype(jnp.int32),
        "rr_dist": lambda: (q_bank - last_bank - 1) % jnp.int32(n_banks),
        "qslot": lambda: jnp.arange(Q, dtype=jnp.int32),
        "write_pressure": lambda: jnp.zeros((Q,), jnp.int32) + jnp.sum(
            (visible & (is_write() != 0)).astype(jnp.int32)),
    }


@dataclasses.dataclass
class Trace:
    """Padded request trace. kind==NOP entries are ignored."""
    kind: np.ndarray    # int32 [N]
    bank: np.ndarray    # int32 [N]
    row: np.ndarray     # int32 [N]
    delta: np.ndarray   # int32 [N] proc cycles of compute before this request
    dep: np.ndarray     # int32 [N] 0 = window-only; d>0 = depends on resp[i-d]

    @property
    def n(self):
        return int(self.kind.shape[0])

    @property
    def n_real(self):
        """Non-NOP request count — input to :func:`slot_budget`."""
        return int((np.asarray(self.kind) != NOP).sum())

    @staticmethod
    def of(kind, bank, row, delta, dep=None):
        kind = np.asarray(kind, np.int32)
        z = np.zeros_like(kind)
        return Trace(kind=kind, bank=np.asarray(bank, np.int32),
                     row=np.asarray(row, np.int32),
                     delta=np.asarray(delta, np.int32),
                     dep=z if dep is None else np.asarray(dep, np.int32))

    def arrays(self):
        return (jnp.asarray(self.kind), jnp.asarray(self.bank),
                jnp.asarray(self.row), jnp.asarray(self.delta),
                jnp.asarray(self.dep))


def _issue_frontier(t_issue, t_resp, queue, kindj, delta, dep, ptr, W, upto=4):
    """Advance the in-order issue pointer by up to ``upto`` requests,
    pushing them into free hardware-queue slots. ``queue`` holds request
    indices (-1 = free); occupancy can never exceed the window W because
    issue is in-order with W outstanding.

    O(1) work per advance: point gathers plus predicated point-scatters
    (``arr.at[i].set(where(can, new, arr[i]))`` — a self-write when the
    advance is disabled), never full-length selects."""
    N = t_issue.shape[0]
    for _ in range(upto):
        j = ptr
        jc = jnp.clip(j, 0, N - 1)
        prev_issue = jnp.where(j > 0, t_issue[jnp.clip(j - 1, 0, N - 1)], 0)
        base = prev_issue + delta[jc]
        wj = j - W
        win_known = (wj < 0) | (t_resp[jnp.clip(wj, 0, N - 1)] < BIG)
        win_t = jnp.where(wj >= 0, t_resp[jnp.clip(wj, 0, N - 1)] + 1, 0)
        dj = j - dep[jc]
        dep_on = dep[jc] > 0
        dep_known = (~dep_on) | (dj < 0) | (t_resp[jnp.clip(dj, 0, N - 1)] < BIG)
        dep_t = jnp.where(dep_on & (dj >= 0), t_resp[jnp.clip(dj, 0, N - 1)] + 1, 0)
        free = queue < 0
        slot = jnp.argmax(free).astype(jnp.int32)
        is_nop = kindj[jc] == 4  # NOP padding: resolve instantly, skip queue
        can = (j < N) & win_known & dep_known & (jnp.any(free) | is_nop)
        t_new = jnp.maximum(jnp.maximum(base, win_t), dep_t)
        t_issue = t_issue.at[jc].set(jnp.where(can, t_new, t_issue[jc]))
        t_resp = t_resp.at[jc].set(jnp.where(can & is_nop, t_new, t_resp[jc]))
        queue = queue.at[slot].set(jnp.where(can & ~is_nop, jc, queue[slot]))
        ptr = jnp.where(can, ptr + 1, ptr)
    return t_issue, t_resp, queue, ptr


def _run_core(kind, bank, row, delta, dep, sys: SystemConfig, mode: str,
              bloom_words, bloom_k: int, bloom_m: int,
              slots: Optional[int] = None):
    """One trace's scan body. Pure traceable function (jit/vmap applied
    by the compile cache below); ``sys``/``mode``/``bloom_k``/``bloom_m``
    and the ``slots`` budget are Python-level constants baked into the
    compiled program. Every per-slot state update is a predicated point
    gather/scatter — O(Q)+O(1) work per slot (see module docstring)."""
    N = kind.shape[0]
    t = sys.timing
    geo = sys.geometry
    W = sys.window
    frfcfs = sys.scheduler == "frfcfs"
    policy = sys.policy
    use_bloom = bloom_words is not None

    # proc cycles per DRAM tick, fixed-point /FP
    scale_num = jnp.int32(round((sys.proc_per_tick_fpga if mode == "nots"
                                 else sys.proc_per_tick_emu) * FP))
    # per-decision MC occupancy (decision *rate*) and per-response latency:
    # ts models the emulated HW MC; nots free-runs against the real SMC
    mc_issue = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                         else sys.hwmc_issue_proc)
    mc_lat = jnp.int32(0 if mode == "nots" else sys.hwmc_latency_proc)
    # a slow SMC batches up whatever arrived while it was busy (nots only)
    vis_slack = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots" else 0)

    Q = max(W, 2)
    state = {
        "bank": dram.init_bank_state(geo),
        "t_issue": jnp.zeros((N,), jnp.int32),
        "t_resp": jnp.full((N,), BIG, jnp.int32),
        "queue": jnp.full((Q,), -1, jnp.int32),  # hardware request buffer
        "ptr": jnp.int32(0),
        "mc_release": jnp.int32(0),     # time-scaling MC counter (proc cycles)
        "dram_now": jnp.int32(0),       # DRAM real-time frontier (ticks)
        "hits": jnp.int32(0),
        "served_n": jnp.int32(0),
        "smc_fpga_cycles": jnp.int32(0),
        "last_bank": jnp.int32(-1),     # bank of the last served request
    }

    kindj, bankj, rowj, deltaj, depj = kind, bank, row, delta, dep

    def slot(state, _):
        t_issue, t_resp = state["t_issue"], state["t_resp"]
        t_issue, t_resp, queue, ptr = _issue_frontier(
            t_issue, t_resp, state["queue"], kindj, deltaj, depj,
            state["ptr"], W)

        # gather queued requests (O(Q), not O(N))
        qvalid = queue >= 0
        qidx = jnp.clip(queue, 0, N - 1)
        q_t = jnp.where(qvalid, t_issue[qidx], BIG)
        q_bank = bankj[qidx]
        q_row = rowj[qidx]

        cutoff = state["mc_release"] + vis_slack
        visible = qvalid & (q_t <= cutoff)
        do = jnp.any(visible)

        # ---- scheduling decision (int32-safe two-level argmin) ----
        open_rows = state["bank"]["open_row"]
        hit_now = open_rows[q_bank] == q_row
        if policy is not None:
            # software-defined path: the policy VM stages the program's
            # instruction table into branchless O(Q) vector ops here
            qslot = smcprog.select_slot(policy, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                state["bank"]["ready"], state["dram_now"],
                state["last_bank"], geo.n_banks, Q), visible)
        else:
            key_all = jnp.where(visible, q_t, BIG)
            key_hit = jnp.where(visible & hit_now, q_t, BIG)
            slot_hit = jnp.argmin(key_hit).astype(jnp.int32)
            slot_old = jnp.argmin(key_all).astype(jnp.int32)
            use_hit = frfcfs & jnp.any(visible & hit_now)
            qslot = jnp.where(use_hit, slot_hit, slot_old)
        pick = qidx[qslot]

        # ---- DRAM service (command-batch executor) ----
        # decision happens when the MC is free AND the request has arrived
        decision_t = jnp.maximum(t_issue[pick], state["mc_release"])
        dram_req_t = jnp.maximum(state["dram_now"],
                                 _mul_div(decision_t, FP, jnp.maximum(scale_num, 1)))
        trcd_eff = jnp.int32(t.tRCD)
        if use_bloom:
            gid = (bankj[pick] * geo.n_rows + rowj[pick]).astype(jnp.uint32)
            weakp = bloom_probe_jnp(bloom_words, bloom_m, bloom_k, gid[None])[0]
            trcd_eff = jnp.where(weakp, jnp.int32(t.tRCD), jnp.int32(t.tRCD_reduced))
        nbs, t_done, hit = dram.service_request(
            state["bank"], t, kindj[pick], bankj[pick], rowj[pick],
            dram_req_t, trcd_eff)

        # ---- time scaling: response consume-tag in modeled proc cycles.
        # t_done is absolute DRAM time; decisions pipeline at mc_issue rate
        # while each response additionally carries the MC pipeline latency.
        resp_t = _mul_div(t_done, scale_num, FP) + mc_lat
        resp_t = jnp.maximum(resp_t, decision_t + mc_issue)

        state = dict(state)
        # bank state advances only at index b: merge the served bank's row
        # of the transition (plus the channel scalars) as predicated point
        # writes instead of whole-array selects
        b = bankj[pick]
        bs = state["bank"]
        state["bank"] = {
            "open_row": bs["open_row"].at[b].set(
                jnp.where(do, nbs["open_row"][b], bs["open_row"][b])),
            "ready": bs["ready"].at[b].set(
                jnp.where(do, nbs["ready"][b], bs["ready"][b])),
            "act_at": bs["act_at"].at[b].set(
                jnp.where(do, nbs["act_at"][b], bs["act_at"][b])),
            "bus_busy": jnp.where(do, nbs["bus_busy"], bs["bus_busy"]),
            "refs_done": jnp.where(do, nbs["refs_done"], bs["refs_done"]),
        }
        state["t_resp"] = t_resp.at[pick].set(
            jnp.where(do, resp_t, t_resp[pick]))
        queue = queue.at[qslot].set(jnp.where(do, -1, queue[qslot]))
        state["dram_now"] = jnp.where(do, jnp.maximum(state["dram_now"], dram_req_t),
                                      state["dram_now"])
        state["hits"] = state["hits"] + jnp.where(do & hit, 1, 0)
        state["served_n"] = state["served_n"] + jnp.where(do, 1, 0)
        state["smc_fpga_cycles"] = state["smc_fpga_cycles"] + jnp.where(
            do, sys.smc_cycles_per_decision + sys.smc_transfer_cycles, 0)
        state["last_bank"] = jnp.where(do, bankj[pick], state["last_bank"])
        # MC busy until the next decision slot; idle hop to the next
        # arrival when nothing is visible — but only when something is
        # queued: hopping on an empty queue (mid-trace NOP run) would
        # saturate the counter to BIG-1 and poison every later response
        # (the pre-PR-4 idle-hop quirk)
        nxt = jnp.min(q_t)
        idle = jnp.where(
            jnp.any(qvalid),
            jnp.maximum(state["mc_release"], jnp.minimum(nxt, BIG - 1)),
            state["mc_release"])
        state["mc_release"] = jnp.where(
            do, jnp.maximum(state["mc_release"], decision_t + mc_issue), idle)
        state["t_issue"], state["queue"], state["ptr"] = t_issue, queue, ptr
        return state, None

    length = (2 * N + 4) if slots is None else slots
    state, _ = jax.lax.scan(slot, state, None, length=length)
    # trailing frontier pass so post-memory compute counts
    t_issue, _, _, ptr = _issue_frontier(
        state["t_issue"], state["t_resp"], state["queue"],
        kindj, deltaj, depj, state["ptr"], W, upto=8)
    valid = kindj != NOP
    served_mask = state["t_resp"] < BIG
    last_resp = jnp.max(jnp.where(valid & served_mask, state["t_resp"], 0))
    last_issue = jnp.max(jnp.where(valid, t_issue, 0))
    return {
        "exec_cycles": jnp.maximum(last_resp, last_issue),
        "row_hits": state["hits"],
        "served": state["served_n"],
        "dram_ticks": state["dram_now"],
        "smc_fpga_cycles": state["smc_fpga_cycles"],
        "t_resp": state["t_resp"],
        "t_issue": t_issue,
    }


# ---------------------------------------------------------------------------
# Reference engine: the pre-optimization core. O(bucket) work per slot
# (full-length predicated selects), uniform 2*bucket+4 budget. Kept ONLY
# to pin bit-exactness (tests/test_property.py) and to measure the
# steady-state speedup (benchmarks --section sim_speed). Do not use for
# new work. Semantic changes are forbidden EXCEPT the ones the fast core
# must stay bit-identical under: the PR-4 policy-VM branch, the
# last_bank carry it reads, and the idle-hop empty-queue fix — all
# mirrored line-for-line from _run_core.
# ---------------------------------------------------------------------------


def _issue_frontier_ref(t_issue, t_resp, queue, kindj, delta, dep, ptr, W,
                        upto=4):
    N = t_issue.shape[0]
    for _ in range(upto):
        j = ptr
        jc = jnp.clip(j, 0, N - 1)
        prev_issue = jnp.where(j > 0, t_issue[jnp.clip(j - 1, 0, N - 1)], 0)
        base = prev_issue + delta[jc]
        wj = j - W
        win_known = (wj < 0) | (t_resp[jnp.clip(wj, 0, N - 1)] < BIG)
        win_t = jnp.where(wj >= 0, t_resp[jnp.clip(wj, 0, N - 1)] + 1, 0)
        dj = j - dep[jc]
        dep_on = dep[jc] > 0
        dep_known = (~dep_on) | (dj < 0) | (t_resp[jnp.clip(dj, 0, N - 1)] < BIG)
        dep_t = jnp.where(dep_on & (dj >= 0), t_resp[jnp.clip(dj, 0, N - 1)] + 1, 0)
        free = queue < 0
        slot = jnp.argmax(free).astype(jnp.int32)
        is_nop = kindj[jc] == 4
        can = (j < N) & win_known & dep_known & (jnp.any(free) | is_nop)
        t_new = jnp.maximum(jnp.maximum(base, win_t), dep_t)
        t_issue = jnp.where(can, t_issue.at[jc].set(t_new), t_issue)
        t_resp = jnp.where(can & is_nop, t_resp.at[jc].set(t_new), t_resp)
        queue = jnp.where(can & ~is_nop, queue.at[slot].set(jc), queue)
        ptr = jnp.where(can, ptr + 1, ptr)
    return t_issue, t_resp, queue, ptr


def _run_core_ref(kind, bank, row, delta, dep, sys: SystemConfig, mode: str,
                  bloom_words, bloom_k: int, bloom_m: int):
    N = kind.shape[0]
    t = sys.timing
    geo = sys.geometry
    W = sys.window
    frfcfs = sys.scheduler == "frfcfs"
    policy = sys.policy
    use_bloom = bloom_words is not None

    scale_num = jnp.int32(round((sys.proc_per_tick_fpga if mode == "nots"
                                 else sys.proc_per_tick_emu) * FP))
    mc_issue = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                         else sys.hwmc_issue_proc)
    mc_lat = jnp.int32(0 if mode == "nots" else sys.hwmc_latency_proc)
    vis_slack = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots" else 0)

    Q = max(W, 2)
    state = {
        "bank": dram.init_bank_state(geo),
        "t_issue": jnp.zeros((N,), jnp.int32),
        "t_resp": jnp.full((N,), BIG, jnp.int32),
        "queue": jnp.full((Q,), -1, jnp.int32),
        "ptr": jnp.int32(0),
        "mc_release": jnp.int32(0),
        "dram_now": jnp.int32(0),
        "hits": jnp.int32(0),
        "served_n": jnp.int32(0),
        "smc_fpga_cycles": jnp.int32(0),
        "last_bank": jnp.int32(-1),
    }

    kindj, bankj, rowj, deltaj, depj = kind, bank, row, delta, dep

    def slot(state, _):
        t_issue, t_resp = state["t_issue"], state["t_resp"]
        t_issue, t_resp, queue, ptr = _issue_frontier_ref(
            t_issue, t_resp, state["queue"], kindj, deltaj, depj,
            state["ptr"], W)

        qvalid = queue >= 0
        qidx = jnp.clip(queue, 0, N - 1)
        q_t = jnp.where(qvalid, t_issue[qidx], BIG)
        q_bank = bankj[qidx]
        q_row = rowj[qidx]

        cutoff = state["mc_release"] + vis_slack
        visible = qvalid & (q_t <= cutoff)
        do = jnp.any(visible)

        open_rows = state["bank"]["open_row"]
        hit_now = open_rows[q_bank] == q_row
        if policy is not None:
            qslot = smcprog.select_slot(policy, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                state["bank"]["ready"], state["dram_now"],
                state["last_bank"], geo.n_banks, Q), visible)
        else:
            key_all = jnp.where(visible, q_t, BIG)
            key_hit = jnp.where(visible & hit_now, q_t, BIG)
            slot_hit = jnp.argmin(key_hit).astype(jnp.int32)
            slot_old = jnp.argmin(key_all).astype(jnp.int32)
            use_hit = frfcfs & jnp.any(visible & hit_now)
            qslot = jnp.where(use_hit, slot_hit, slot_old)
        pick = qidx[qslot]

        decision_t = jnp.maximum(t_issue[pick], state["mc_release"])
        dram_req_t = jnp.maximum(state["dram_now"],
                                 _mul_div(decision_t, FP, jnp.maximum(scale_num, 1)))
        trcd_eff = jnp.int32(t.tRCD)
        if use_bloom:
            gid = (bankj[pick] * geo.n_rows + rowj[pick]).astype(jnp.uint32)
            weakp = bloom_probe_jnp(bloom_words, bloom_m, bloom_k, gid[None])[0]
            trcd_eff = jnp.where(weakp, jnp.int32(t.tRCD), jnp.int32(t.tRCD_reduced))
        nbs, t_done, hit = dram.service_request(
            state["bank"], t, kindj[pick], bankj[pick], rowj[pick],
            dram_req_t, trcd_eff)

        resp_t = _mul_div(t_done, scale_num, FP) + mc_lat
        resp_t = jnp.maximum(resp_t, decision_t + mc_issue)

        state = dict(state)
        state["bank"] = jax.tree_util.tree_map(
            lambda a, b: jnp.where(do, b, a), state["bank"], nbs)
        state["t_resp"] = jnp.where(do, t_resp.at[pick].set(resp_t), t_resp)
        queue = jnp.where(do, queue.at[qslot].set(-1), queue)
        state["dram_now"] = jnp.where(do, jnp.maximum(state["dram_now"], dram_req_t),
                                      state["dram_now"])
        state["hits"] = state["hits"] + jnp.where(do & hit, 1, 0)
        state["served_n"] = state["served_n"] + jnp.where(do, 1, 0)
        state["smc_fpga_cycles"] = state["smc_fpga_cycles"] + jnp.where(
            do, sys.smc_cycles_per_decision + sys.smc_transfer_cycles, 0)
        state["last_bank"] = jnp.where(do, bankj[pick], state["last_bank"])
        # idle-hop fix mirrored from _run_core: never hop on an empty queue
        nxt = jnp.min(q_t)
        idle = jnp.where(
            jnp.any(qvalid),
            jnp.maximum(state["mc_release"], jnp.minimum(nxt, BIG - 1)),
            state["mc_release"])
        state["mc_release"] = jnp.where(
            do, jnp.maximum(state["mc_release"], decision_t + mc_issue), idle)
        state["t_issue"], state["queue"], state["ptr"] = t_issue, queue, ptr
        return state, None

    state, _ = jax.lax.scan(slot, state, None, length=2 * N + 4)
    t_issue, _, _, ptr = _issue_frontier_ref(
        state["t_issue"], state["t_resp"], state["queue"],
        kindj, deltaj, depj, state["ptr"], W, upto=8)
    valid = kindj != NOP
    served_mask = state["t_resp"] < BIG
    last_resp = jnp.max(jnp.where(valid & served_mask, state["t_resp"], 0))
    last_issue = jnp.max(jnp.where(valid, t_issue, 0))
    return {
        "exec_cycles": jnp.maximum(last_resp, last_issue),
        "row_hits": state["hits"],
        "served": state["served_n"],
        "dram_ticks": state["dram_now"],
        "smc_fpga_cycles": state["smc_fpga_cycles"],
        "t_resp": state["t_resp"],
        "t_issue": t_issue,
    }


def pad_trace(tr: Trace, n: int) -> Trace:
    """Pad with NOPs to length n (keeps jit caches warm across sizes)."""
    k = n - tr.n
    assert k >= 0
    z = np.zeros(k, np.int32)
    return Trace(kind=np.concatenate([tr.kind, z + 4]),
                 bank=np.concatenate([tr.bank, z]),
                 row=np.concatenate([tr.row, z]),
                 delta=np.concatenate([tr.delta, z]),
                 dep=np.concatenate([tr.dep, z]))


def _bucket(n: int) -> int:
    b = 32
    while b < n:
        b *= 2
    return b


def slot_budget(bucket: int, n_real: int) -> int:
    """Exact scan-slot budget for a batch group padded to ``bucket``
    whose largest trace has ``n_real`` non-NOP requests:

        2 * Rq + ceil((bucket - Rq) / 4) + 4

    with Rq = n_real rounded up to a ``max(bucket // 4, 8)`` granule
    (capped at bucket). Real requests cost at most 2 slots each (idle
    hop + serve, with issue piggybacking on earlier slots); NOPs resolve
    4 per slot in the frontier and never enter the queue. The budget is
    monotone in n_real, so the group max covers every member; surplus
    slots are no-ops, keeping results bit-identical to any larger
    budget (2*bucket+4 degenerate case included)."""
    g = max(bucket // 4, 8)
    rq = min(bucket, -(-n_real // g) * g)
    return 2 * rq + (bucket - rq + 3) // 4 + 4


def _batch_bucket(b: int) -> int:
    """Pad the batch axis to a power of two so sweeps of nearby sizes
    share one executable (padding rows are all-NOP traces)."""
    p = 1
    while p < b:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Batched campaigns: module-level compile cache over vmapped executables.
# LRU-bounded (``REPRO_EMU_CACHE_CAP`` / :func:`set_cache_capacity`) so an
# unbounded sweep of distinct compile keys cannot retain every executable
# it ever built; evictions are counted in :func:`cache_stats`. A second
# *process* re-running the same sweep skips the XLA compile entirely when
# the persistent on-disk cache is enabled
# (:func:`repro.utils.jax_compat.enable_persistent_compile_cache`).
# ---------------------------------------------------------------------------

_COMPILE_CACHE: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_CACHE_LOCK = threading.Lock()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_CACHE_CAP = max(1, executor._env_int("REPRO_EMU_CACHE_CAP", 128))

# batch-axis device sharding of run_many executables:
#   'auto'  — shard_map over local devices when >1 is present and the
#             padded batch axis divides across them; plain vmap otherwise
#   'off'   — never wrap in shard_map
#   'force' — always wrap, even over a single-device mesh (exercises the
#             shard_map code path on 1-device hosts; bit-identical)
_SHARD_MODES = ("auto", "off", "force")
_SHARD_MODE = os.environ.get("REPRO_EXEC_SHARD", "auto")


def set_sharding(mode: str) -> str:
    """Set the batch-axis sharding mode ('auto' | 'off' | 'force');
    returns the previous mode. Sharded and unsharded executables live
    under distinct cache keys, so toggling never returns a stale fn."""
    global _SHARD_MODE
    if mode not in _SHARD_MODES:
        raise ValueError(
            f"sharding mode must be one of {_SHARD_MODES}, got {mode!r}")
    old, _SHARD_MODE = _SHARD_MODE, mode
    return old


def _shard_count(batch: int) -> int:
    """Number of mesh devices for a padded batch axis of ``batch``:
    0 = no shard_map wrapper; >= 1 = wrap over that many devices (1 only
    under 'force'). The padded batch is a power of two, so the largest
    power-of-two device count that divides it is used."""
    if _SHARD_MODE == "off":
        return 0
    ndev = jax.local_device_count()
    n = 1
    while n * 2 <= ndev and batch % (n * 2) == 0:
        n *= 2
    if n == 1 and _SHARD_MODE != "force":
        return 0
    return n


def _norm_mode(mode: str) -> str:
    """'reference' compiles to the exact 'ts' program — that coincidence
    IS the paper's time-scaling claim — so they share one executable."""
    return "ts" if mode == "reference" else mode


def _is_bloom_triple(b) -> bool:
    """One (words_u32, k, m_bits) filter: words array + two scalars (as
    opposed to a per-trace sequence of such triples)."""
    return (len(b) == 3 and not isinstance(b[0], (tuple, list))
            and np.ndim(b[1]) == 0 and np.ndim(b[2]) == 0)


def _bloom_shape(blooms) -> Optional[tuple]:
    """Shape signature of a blooms argument: None, one shared (words, k,
    m_bits) filter, or a per-trace sequence of identically-shaped
    triples — shared-vs-stacked decided by content (like
    :func:`_normalize_blooms`), not container type."""
    if blooms is None:
        return None
    if _is_bloom_triple(blooms):
        return ("shared", int(np.asarray(blooms[0]).shape[0]),
                blooms[1], blooms[2])
    b0 = tuple(blooms[0])
    return ("stacked", int(np.asarray(b0[0]).shape[0]), b0[1], b0[2])


def group_key(n: int, sys: SystemConfig, mode: str, blooms) -> tuple:
    """Grouping key for one trace-length-n point: everything a batched
    executable is specialized on EXCEPT the batch axis and slot budget,
    which only exist once a group is assembled (run_many derives them
    per group). One source of truth with :func:`compile_key` for the
    bucket / mode / bloom-shape normalization — used by
    :class:`repro.core.campaign.Campaign`."""
    return (_bucket(n), sys, _norm_mode(mode), _bloom_shape(blooms))


def compile_key(bucket: int, batch: int, sys: SystemConfig, mode: str,
                blooms, slots: Optional[int] = None) -> tuple:
    """Cache key for one batched executable (see :func:`_bloom_shape`
    for the ``blooms`` normalization). ``slots`` is the group's
    :func:`slot_budget` (None for the uniform-budget reference
    engine). ``sys`` carries the policy program, which hashes by
    instruction-table content (digest semantics): same-content programs
    share one executable, distinct programs fork the key — so a policy
    grid runs one batched dispatch per program."""
    return (bucket, slots, _batch_bucket(batch), sys, _norm_mode(mode),
            _bloom_shape(blooms))


def cache_stats() -> dict:
    """Executable-cache counters since the last :func:`cache_clear`:
    ``hits`` / ``misses`` (misses == in-process compiles) over
    :func:`run_many` lookups, ``evictions`` (LRU drops past
    ``capacity``), plus current ``size`` / ``capacity``. ``persistent``
    mirrors the on-disk XLA cache counters when
    :func:`repro.utils.jax_compat.enable_persistent_compile_cache` is
    active (all-zero otherwise)."""
    from repro.utils import jax_compat
    with _CACHE_LOCK:
        out = dict(_CACHE_STATS)
        out["size"] = len(_COMPILE_CACHE)
        out["capacity"] = _CACHE_CAP
    out["persistent"] = jax_compat.persistent_cache_stats()
    return out


def cache_clear() -> None:
    """Drop every cached executable and zero ALL counters (hits,
    misses, and the eviction counter added with the LRU bound)."""
    with _CACHE_LOCK:
        _COMPILE_CACHE.clear()
        for k in _CACHE_STATS:
            _CACHE_STATS[k] = 0


def set_cache_capacity(n: int) -> int:
    """Bound the in-memory executable cache to ``n`` entries (LRU);
    returns the previous capacity. Shrinking evicts immediately."""
    global _CACHE_CAP
    if n < 1:
        raise ValueError(f"cache capacity must be >= 1, got {n}")
    with _CACHE_LOCK:
        old, _CACHE_CAP = _CACHE_CAP, n
        while len(_COMPILE_CACHE) > _CACHE_CAP:
            _COMPILE_CACHE.popitem(last=False)
            _CACHE_STATS["evictions"] += 1
    return old


def _shard_wrap(fn, nshards: int, bshape):
    """Wrap a batched runner in ``shard_map`` over ``nshards`` local
    devices on the (leading) batch axis. Trace arrays shard; a shared
    Bloom filter replicates; stacked per-trace filters shard. Inside
    each shard the wrapped fn sees a ``batch/nshards`` slice and vmaps
    over it exactly as in the unsharded path, so results concatenate to
    the bit-identical full batch."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.utils import jax_compat
    mesh = Mesh(np.array(jax.local_devices()[:nshards]), ("batch",))
    spec = P("batch")
    if bshape is None:
        in_specs = (spec,) * 5
    else:
        in_specs = (spec,) * 5 + (spec if bshape[0] == "stacked" else P(),)
    return jax_compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=spec,
                                **jax_compat.shard_map_kwargs())


class _CachedRunner:
    """One cached executable: a lazily-compiled jitted runner plus the
    argument shapes its compile key fixes.

    :meth:`prime` compiles it NOW, on the calling thread, by running an
    all-zeros dummy batch (all-NOP-free zero reads; one scan execution,
    noise next to the compile). ``prepare_tasks`` primes every resolved
    runner in group order on the caller's thread before any executor
    worker starts, which buys two properties the lazy first-call would
    lose: (a) tracing/lowering interleaved across worker threads makes
    jax's uid counters — and so the emitted StableHLO bytes and the
    persistent on-disk cache key — nondeterministic across processes
    (observed: one fresh disk entry per run); (b) only the *warmed* C++
    jit fast path executes synchronously on the calling thread under
    the inline CPU runtime — an unwarmed call (and the AOT
    ``Lowered.compile()(...)`` path) enqueues onto the device's single
    execute thread, which silently serializes the overlapped groups."""

    __slots__ = ("jitted", "avals", "primed")

    def __init__(self, jitted, avals):
        self.jitted = jitted
        self.avals = avals
        self.primed = False

    def prime(self) -> "_CachedRunner":
        # donation warning noise is suppressed by the module-level
        # filter (a per-call catch_warnings here would race: it mutates
        # process-global filter state while workers may be executing)
        if not self.primed:
            self.jitted(*(jnp.zeros(s, d) for s, d in self.avals))
            self.primed = True
        return self

    def __call__(self, *args):
        return self.jitted(*args)


def _batched_fn(key: tuple, ref: bool = False):
    """Jitted vmapped runner for one compile key; built once per key,
    LRU-retained up to the cache capacity (a :class:`_CachedRunner`,
    compiled on first :meth:`~_CachedRunner.prime` or call). ``ref=True``
    builds the pre-optimization reference engine (no slot budget, no
    donation) on a separate cache entry. When batch-axis sharding
    applies (see :func:`set_sharding`), the runner is shard_mapped over
    the local devices — sharded and unsharded variants fork the cache
    key, so counter semantics are unchanged for a fixed device
    topology."""
    batch = key[2]
    nshards = _shard_count(batch)
    ckey = ("ref" if ref else "fast", nshards, key)
    # get-or-create is atomic: the lock is held across the whole build
    # (cheap — jit wrapping and Mesh construction; the XLA compile is
    # deferred to prime()/first call), so two threads racing on one key
    # can neither duplicate the entry nor skew the hit/miss counters
    with _CACHE_LOCK:
        fn = _COMPILE_CACHE.get(ckey)
        if fn is not None:
            _CACHE_STATS["hits"] += 1
            _COMPILE_CACHE.move_to_end(ckey)
            return fn
        _CACHE_STATS["misses"] += 1
        runner = _build_runner(key, ref, nshards)
        _COMPILE_CACHE[ckey] = runner
        while len(_COMPILE_CACHE) > _CACHE_CAP:
            _COMPILE_CACHE.popitem(last=False)
            _CACHE_STATS["evictions"] += 1
    return runner


def _build_runner(key: tuple, ref: bool, nshards: int) -> "_CachedRunner":
    """Construct the (lazily-compiled) runner for one cache key."""
    _, slots, batch, sys, mode, bshape = key
    core = _run_core_ref if ref else _run_core
    extra = {} if ref else {"slots": slots}

    if bshape is None:
        def fn(kind, bank, row, delta, dep):
            return jax.vmap(lambda k, b, r, d, dp: core(
                k, b, r, d, dp, sys, mode, None, 0, 1, **extra))(
                kind, bank, row, delta, dep)
    else:
        stacked, _, bk, bm = bshape
        words_axis = 0 if stacked == "stacked" else None

        def fn(kind, bank, row, delta, dep, words):
            return jax.vmap(
                lambda k, b, r, d, dp, w: core(
                    k, b, r, d, dp, sys, mode, w, bk, bm, **extra),
                in_axes=(0, 0, 0, 0, 0, words_axis))(
                kind, bank, row, delta, dep, words)

    if nshards:
        fn = _shard_wrap(fn, nshards, bshape)

    # trace arrays are freshly staged from host memory every call, so the
    # executable may reuse their buffers for its outputs (bloom words can
    # be caller-shared jnp arrays -> not donated); donation is best-effort
    # by design, so the inputs-not-aliased warning is pure noise
    jitted = jax.jit(fn) if ref else jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4))
    bucket, bb = key[0], _batch_bucket(batch)
    avals = [((bb, bucket), jnp.int32)] * 5
    if bshape is not None:
        wshape = (bshape[1],) if bshape[0] == "shared" else (bb, bshape[1])
        avals = avals + [(wshape, jnp.uint32)]
    return _CachedRunner(jitted, avals)


def _finalize(out_row: dict, padded: Trace, sys: SystemConfig,
              mode: str) -> dict:
    """Per-trace derived metrics — identical math to the original
    single-trace ``run`` so batched results stay drop-in compatible."""
    out = {kk: np.asarray(v) for kk, v in out_row.items()}
    out["exec_seconds"] = sys.cycles_to_seconds(out["exec_cycles"], mode)
    out["mode"] = mode
    out["n_requests"] = int((padded.kind != NOP).sum())
    lat = out["t_resp"] - out["t_issue"]
    ok = (padded.kind != NOP) & (out["t_resp"] < int(BIG))
    out["avg_load_latency_cycles"] = float(lat[ok].mean()) if ok.any() else 0.0
    return out


def _normalize_blooms(blooms, n: int):
    """blooms: None | one (words, k, m_bits) filter (any sequence type)
    | a per-trace sequence of identically-shaped filter triples. ->
    None | shared tuple | list of tuples (no mixed None: group
    upstream). Shared-vs-per-trace is decided by content, not container
    type, so a list-typed single filter still broadcasts."""
    if blooms is None:
        return None
    blooms = list(blooms)
    if _is_bloom_triple(blooms):
        return tuple(blooms)
    blooms = [tuple(b) for b in blooms]
    assert len(blooms) == n, "per-trace blooms must match len(traces)"
    b0 = blooms[0]
    assert all(_is_bloom_triple(b) and b[1] == b0[1] and b[2] == b0[2]
               and np.asarray(b[0]).shape == np.asarray(b0[0]).shape
               for b in blooms), \
        "per-trace blooms must share (words-shape, k, m_bits); use " \
        "Campaign to mix bloom/no-bloom points in one grid"
    return blooms


def check_mode(mode: str) -> str:
    """Validate one evaluation mode; a real ValueError (not an assert
    — asserts vanish under ``python -O``) carrying the offending value.
    Single source of truth for every mode guard (``run`` / ``run_many``
    / ``Campaign.add`` / ``Campaign.add_policy_grid``)."""
    if mode not in ("ts", "nots", "reference"):
        raise ValueError(
            f"mode must be one of ('ts', 'nots', 'reference'), got {mode!r}")
    return mode


def _check_modes(modes: Sequence[str], n: int) -> List[str]:
    modes = list(modes)
    if len(modes) != n:
        raise ValueError(
            f"per-trace modes ({len(modes)}) must match len(traces) ({n})")
    for m in modes:
        check_mode(m)
    return modes


def prepare_tasks(traces: Sequence[Trace], sys: SystemConfig,
                  mode: Union[str, Sequence[str]], blooms,
                  results: List[Optional[dict]], ref: bool = False,
                  ) -> List[executor.GroupTask]:
    """Plan one :func:`run_many`-style call into executable
    :class:`repro.core.executor.GroupTask`s WITHOUT running them.

    Grouping, executable-cache resolution (``_batched_fn`` — so
    ``cache_stats`` counters settle deterministically on the caller's
    thread, in group order), and slot budgeting happen here; the
    host-side padding/stacking and the device dispatch are deferred
    into each task's ``pack``/``run``, which is what lets the
    campaign executor overlap group k+1's packing with group k's
    compute. Each task finalizes into its own ``results`` slots
    (``results`` must be a list of ``len(traces)`` Nones).
    """
    traces = list(traces)
    n = len(traces)
    modes = _check_modes([mode] * n if isinstance(mode, str) else mode, n)
    blooms = _normalize_blooms(blooms, n)

    groups: dict = {}  # (bucket, normalized mode) -> [trace index]
    for i, tr in enumerate(traces):
        groups.setdefault((_bucket(tr.n), _norm_mode(modes[i])), []).append(i)

    tasks: List[executor.GroupTask] = []
    for (bucket, gmode), idxs in groups.items():
        slots = None if ref else slot_budget(
            bucket, max(traces[i].n_real for i in idxs))
        key = compile_key(bucket, len(idxs), sys, gmode, blooms, slots)
        fn = _batched_fn(key, ref=ref).prime()

        def pack(idxs=idxs, bucket=bucket):
            padded = [pad_trace(traces[i], bucket) for i in idxs]
            bb = _batch_bucket(len(idxs))
            if bb > len(idxs):  # all-NOP filler rows, discarded below
                filler = Trace.of(np.full(bucket, 4), np.zeros(bucket),
                                  np.zeros(bucket), np.zeros(bucket))
                padded += [filler] * (bb - len(idxs))
            stacked = [jnp.asarray(np.stack([getattr(p, f) for p in padded]))
                       for f in ("kind", "bank", "row", "delta", "dep")]
            if blooms is None:
                args = tuple(stacked)
            elif isinstance(blooms, tuple):
                args = (*stacked, jnp.asarray(blooms[0]))
            else:
                words = np.stack([np.asarray(blooms[i][0]) for i in idxs])
                if bb > len(idxs):
                    words = np.concatenate(
                        [words, np.repeat(words[:1], bb - len(idxs), axis=0)])
                args = (*stacked, jnp.asarray(words))
            return args, padded

        def finalize(out, padded, idxs=idxs):
            for j, i in enumerate(idxs):
                row = {kk: v[j] for kk, v in out.items()}
                results[i] = _finalize(row, padded[j], sys, modes[i])

        tasks.append(executor.GroupTask(
            fn=fn, pack=pack, finalize=finalize,
            label=f"b{bucket}x{len(idxs)}:{gmode}",
            cost=(slots or 2 * bucket + 4) * _batch_bucket(len(idxs))))
    return tasks


def _run_grouped(traces: Sequence[Trace], sys: SystemConfig,
                 mode: Union[str, Sequence[str]], blooms,
                 ref: bool, serial: Optional[bool] = None) -> List[dict]:
    """Shared grouped-execution path for :func:`run_many` (exact slot
    budgets) and :func:`run_ref_many` (uniform reference budgets):
    plan into group tasks, then execute — overlapped across the
    executor's worker pool when more than one group is present, or
    strictly in-order under ``serial=True``. Bit-identical either way
    (the executor only changes wall-clock interleaving)."""
    traces = list(traces)
    results: List[Optional[dict]] = [None] * len(traces)
    tasks = prepare_tasks(traces, sys, mode, blooms, results, ref=ref)
    executor.execute(tasks, serial=serial)
    return results


def run_many(traces: Sequence[Trace], sys: SystemConfig,
             mode: Union[str, Sequence[str]] = "ts",
             blooms=None, serial: Optional[bool] = None) -> List[dict]:
    """Evaluate many traces under one ``SystemConfig`` in batched calls.

    ``mode`` is one of 'ts' | 'nots' | 'reference', or a per-trace
    sequence of them. ``blooms`` is None, one shared ``(words, k,
    m_bits)`` tuple, or a per-trace list of identically-shaped tuples
    (stacked and vmapped alongside the traces).

    Traces are grouped by ``(length-bucket, mode)``; each group pads to
    its bucket, pads the batch axis to a power of two with all-NOP
    traces, computes its exact :func:`slot_budget` from the largest
    member, and executes as ONE vmapped, jit-cached call (trace buffers
    donated; batch axis sharded across local devices when present —
    see :func:`set_sharding`). Multi-group calls overlap host packing
    with device compute across the ``repro.core.executor`` worker pool;
    ``serial=True`` forces the in-order loop (bit-identical, for A/B).
    Returns one dict per input trace, in input order, bit-identical to
    ``run(trace, sys, mode, bloom)``.
    """
    return _run_grouped(traces, sys, mode, blooms, ref=False, serial=serial)


def run_ref_many(traces: Sequence[Trace], sys: SystemConfig,
                 mode: Union[str, Sequence[str]] = "ts",
                 blooms=None, serial: Optional[bool] = None) -> List[dict]:
    """The pre-optimization engine over the same grouped/batched path:
    O(bucket) work per slot, uniform ``2*bucket+4`` budget. Kept for
    bit-exactness property tests and the sim_speed steady-state A/B."""
    return _run_grouped(traces, sys, mode, blooms, ref=True, serial=serial)


def run(trace: Trace, sys: SystemConfig, mode: str = "ts",
        bloom: Optional[tuple] = None) -> dict:
    """mode: 'ts' | 'nots' | 'reference'. bloom: (words_u32, k, m_bits).

    'reference' is the Sec. 6 RTL reference system: a hardware memory
    controller at the modeled clock. Its math must coincide with 'ts' —
    that coincidence (validated in tests/benchmarks) IS the paper's
    time-scaling accuracy claim.

    A thin wrapper over a :func:`run_many` batch of one — single-trace
    and campaign paths share one compiled-program cache.
    """
    return run_many([trace], sys, mode=mode, blooms=bloom)[0]


def run_ref(trace: Trace, sys: SystemConfig, mode: str = "ts",
            bloom: Optional[tuple] = None) -> dict:
    """Single-trace wrapper over :func:`run_ref_many` (see there)."""
    return run_ref_many([trace], sys, mode=mode, blooms=bloom)[0]
