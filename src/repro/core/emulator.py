"""The EasyDRAM engine: trace-driven, multi-domain, time-scaled emulation.

One fused ``lax.scan`` implements the whole request lifetime of Fig. 6:
processor issue (bounded-window in-order front end) -> hardware request
buffer -> SMC critical mode (visibility cutoff on the time-scaling
counter) -> scheduling decision -> DRAM-Bender-style command-batch
execution on the bank state machine -> response tagged with its consume
cycle -> counter advance.

The scheduling decision is software-defined: when ``sys.policy`` is a
:class:`repro.core.smcprog.PolicyProgram`, its instruction table is
interpreted inside the slot body by the branchless policy VM
(O(program-length * Q) extra work per slot, preserving the O(Q)
invariant below); otherwise the legacy hard-coded ``sys.scheduler``
FR-FCFS/FCFS branch runs. The built-in FR-FCFS/FCFS programs are
bit-identical to the legacy flag (tests/test_smcprog.py). The program's
content rides in the compile key through ``SystemConfig`` (programs
hash by table content), so policy sweeps group per program in
:func:`run_many` / ``Campaign``.

Each scan step performs one SMC scheduling slot (serve one visible
request, or an idle hop to the next arrival). All arithmetic is exact
int32 (DRAM ticks / processor cycles, fixed-point 1/4096 conversion);
results are bit-reproducible, which is what lets the Sec. 6 validation
assert exact invariance of time-scaled results to FPGA-side clocks.

Per-slot cost model (the O(Q) invariant)
----------------------------------------

The slot body does O(Q) + O(1) work, where Q = max(window, 2) is the
hardware-queue depth — NOT O(N) in the trace length: every state update
is a predicated point-scatter ``arr.at[i].set(where(pred, new, arr[i]))``
(a self-write when disabled), which XLA keeps in place on the scan carry,
and every read is a point gather. A whole trace therefore costs
O(slots * Q), linear in the trace, where the slot count is the exact
per-batch budget below. The pre-optimization engine (kept verbatim as
:func:`run_ref` / ``_run_core_ref`` for A/B tests and benchmarks) instead
paid full-length predicated selects per slot — O(bucket) work per slot,
O(bucket^2) per trace.

Slot budget
-----------

A real (non-NOP) request needs at most 2 slots (an idle hop that parks
the MC counter at its arrival, then its serve); NOPs (mid-trace or
trailing padding) resolve in the issue frontier at 4 per slot and never
enter the queue. (The idle hop is skipped outright while the hardware
queue is empty — e.g. during a mid-trace NOP run that drains it — so
the MC counter stays parked instead of saturating to BIG-1; the
pre-PR-4 engines saturated there and poisoned every later response.
Both engines carry the fix identically.) For a batch
group padded to ``bucket`` whose largest trace has R real requests, the
scan therefore runs

    slots = 2 * Rq + ceil((bucket - Rq) / 4) + 4,   Rq = R rounded up to
                                                    a bucket/4 granule

slots instead of the previous uniform ``2 * bucket + 4``. Rounding R up
to a coarse granule (and folding ``slots`` into the compile key) keeps
nearby batch shapes on one cached executable; the extra slots are no-ops
(the scan is idempotent once every request is served), so results are
bit-identical for any budget at or above the exact one — asserted by the
property tests against the reference engine.

Entry points:

* :func:`run` — one trace, one config, one mode. A thin wrapper over a
  batch of one.
* :func:`run_many` — a batched campaign step: pads every trace to one
  length bucket, stacks them on a leading axis, and ``jax.vmap``s the
  scan over that axis (optionally over per-trace Bloom filters too), so
  a whole sweep shares ONE compile and ONE device dispatch. Compiled
  executables are cached at module level keyed on
  ``(bucket, slots, batch, sys, mode, bloom-shape)`` — repeated sweeps
  never recompile (see :func:`cache_stats`; the cache is LRU-bounded,
  :func:`set_cache_capacity`). With more than one local device the
  padded batch axis is ``shard_map``-sharded across them
  (:func:`set_sharding`), and multi-group calls execute overlapped
  through ``repro.core.executor`` (``serial=True`` forces the in-order
  loop). Trace buffers are donated to the executable (they are rebuilt
  from host arrays each call). Results are bit-identical to per-trace
  :func:`run` in every combination. For grids that also vary
  ``SystemConfig`` / technique, drive this through
  :class:`repro.core.campaign.Campaign`. A fresh process can skip the
  cold compiles entirely via
  :func:`repro.utils.jax_compat.enable_persistent_compile_cache`.
* :func:`run_ref` / :func:`run_ref_many` — the pre-optimization
  O(bucket)-per-slot engine, kept only to pin bit-exactness and to
  measure the steady-state speedup in ``benchmarks/run.py --section
  sim_speed``.
* :func:`run_stream` / :func:`run_stream_many` — constant-memory
  streaming drivers for unbounded traces: the same slot body scans
  fixed-size windows of ``chunk`` requests while an explicit
  :class:`EmulatorState` carry (plus a ``halo`` of trailing trace
  context) threads across windows. Compile keys depend only on
  ``(chunk, halo, slots, batch, sys, mode, bloom-shape)`` — never on
  total trace length — so a 1M-request stream holds exactly ONE cache
  entry and runs in O(batch * window) device memory. Results are
  bit-identical to single-shot :func:`run` on any size both support
  (see the freeze-rule note on :func:`_stream_step_core`).

Note on XLA:CPU: the thunk runtime (jaxlib >= 0.4.32 default) executes
the tiny per-slot ops of this scan through its intra-op thread pool and
defeats in-place carry updates — a ~30x steady-state slowdown. Benchmark
and example entry points call
:func:`repro.utils.jax_compat.enable_fast_cpu_scan` before the backend
initializes to select the legacy inline runtime.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import warnings
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dram, executor, faults as faultmod, smcprog
from repro.core.bloom import bloom_probe_jnp
from repro.core.dram import NOP, WRITE
from repro.core.timescale import SystemConfig

BIG = jnp.int32(2 ** 30)
FP = 4096  # fixed-point denominator for tick<->cycle conversion
# issue-frontier advances per scheduling slot; the streaming freeze rule
# and halo sizing are derived from it, so it is a named constant
_FRONTIER_UPTO = 4

# donation is best-effort by design (see _batched_fn); the per-call
# catch_warnings there is not thread-safe (process-global filter state),
# so overlapped group execution needs the filter installed up front too
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

def _mul_div(a, num, den):
    """Exact a * num // den without int32 overflow (num, den ~ 1e3..1e4)."""
    q = a // den
    r = a - q * den
    return q * num + (r * num) // den


def _policy_env(q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                bank_ready, dram_now, last_bank, n_banks: int, Q: int,
                fault_hct=None, fault_seed: int = 0):
    """Scheduling environment for the policy VM: one thunk per load op,
    each returning a [Q] int32 vector. :func:`smcprog.evaluate` calls
    only the thunks the program references (and each at most once), so
    an FR-FCFS program pays for exactly the two vectors the hard-coded
    scheduler already computed. Shared by both engine cores so the
    policy semantics cannot drift between them.

    ``fault_hct`` is the fault model's per-bank aggressor ACT counter
    vector (None on a perfect memory — then ``hammer_ct`` loads zeros
    and a TRR mitigation policy degrades to a no-op); ``fault_seed``
    keys the ``para_rand`` draws (see repro.core.faults.para_draw)."""
    is_write = lambda: (kindj[qidx] == WRITE).astype(jnp.int32)  # noqa: E731
    return {
        "age": lambda: q_t,
        "age_rel": lambda: q_t - jnp.min(jnp.where(visible, q_t, BIG)),
        "row_hit": lambda: hit_now.astype(jnp.int32),
        "bank": lambda: q_bank,
        "row": lambda: q_row,
        "is_write": is_write,
        "bank_busy": lambda: (bank_ready[q_bank] > dram_now).astype(jnp.int32),
        "rr_dist": lambda: (q_bank - last_bank - 1) % jnp.int32(n_banks),
        "qslot": lambda: jnp.arange(Q, dtype=jnp.int32),
        "write_pressure": lambda: jnp.zeros((Q,), jnp.int32) + jnp.sum(
            (visible & (is_write() != 0)).astype(jnp.int32)),
        "hammer_ct": lambda: (jnp.zeros((Q,), jnp.int32) if fault_hct is None
                              else fault_hct[q_bank]),
        "para_rand": lambda: faultmod.para_draw(
            fault_seed, q_bank, q_row, dram_now),
    }


@dataclasses.dataclass
class Trace:
    """Padded request trace. kind==NOP entries are ignored."""
    kind: np.ndarray    # int32 [N]
    bank: np.ndarray    # int32 [N]
    row: np.ndarray     # int32 [N]
    delta: np.ndarray   # int32 [N] proc cycles of compute before this request
    dep: np.ndarray     # int32 [N] 0 = window-only; d>0 = depends on resp[i-d]

    @property
    def n(self):
        return int(self.kind.shape[0])

    @property
    def n_real(self):
        """Non-NOP request count — input to :func:`slot_budget`."""
        return int((np.asarray(self.kind) != NOP).sum())

    @staticmethod
    def of(kind, bank, row, delta, dep=None):
        kind = np.asarray(kind, np.int32)
        z = np.zeros_like(kind)
        return Trace(kind=kind, bank=np.asarray(bank, np.int32),
                     row=np.asarray(row, np.int32),
                     delta=np.asarray(delta, np.int32),
                     dep=z if dep is None else np.asarray(dep, np.int32))

    def arrays(self):
        return (jnp.asarray(self.kind), jnp.asarray(self.bank),
                jnp.asarray(self.row), jnp.asarray(self.delta),
                jnp.asarray(self.dep))


@dataclasses.dataclass
class EmulatorState:
    """The complete scan carry of the emulation engine, as an explicit
    pytree (registered dataclass) instead of an ad-hoc dict.

    Everything the slot body threads from one scheduling slot to the
    next lives here: the DRAM bank state machine, per-request issue /
    response tags, the hardware request queue (request indices, -1 =
    free), the in-order issue pointer, the two clock domains
    (``mc_release`` in modeled proc cycles, ``dram_now`` in DRAM
    ticks), and the served/hit/SMC counters. The policy VM is pure per
    slot and Bloom words are read-only operands, so neither needs a
    carry slot. Because the carry is explicit it can be paused,
    serialized (:meth:`to_host` / :meth:`from_host`) and resumed — the
    mechanism the streaming drivers (:func:`run_stream`) use to thread
    one state across fixed-size trace windows. Index fields
    (``t_issue`` / ``t_resp`` / ``queue`` / ``ptr``) are window-local
    there; times stay absolute (int32 horizon ~2^30 cycles)."""
    bank: dict              # DRAM bank state (dram.init_bank_state)
    t_issue: jnp.ndarray    # int32 [N] issue tag per request
    t_resp: jnp.ndarray     # int32 [N] response tag (BIG = unserved)
    queue: jnp.ndarray      # int32 [Q] hardware request buffer
    ptr: jnp.ndarray        # int32 in-order issue pointer
    mc_release: jnp.ndarray  # time-scaling MC counter (proc cycles)
    dram_now: jnp.ndarray   # DRAM real-time frontier (ticks)
    hits: jnp.ndarray       # row-hit counter
    served_n: jnp.ndarray   # serve-slot counter
    smc_fpga_cycles: jnp.ndarray
    last_bank: jnp.ndarray  # bank of the last served request
    # fault-injection carry (repro.core.faults.init_fault_state): {} on a
    # perfect memory, which adds ZERO pytree leaves — the staged carry,
    # and therefore the compiled program, is byte-identical to a build
    # that never heard of faults
    faults: dict = dataclasses.field(default_factory=dict)

    @staticmethod
    def init(n: int, sys: SystemConfig) -> "EmulatorState":
        """Fresh single-shot state for an n-request trace."""
        return EmulatorState(
            bank=dram.init_bank_state(sys.geometry),
            t_issue=jnp.zeros((n,), jnp.int32),
            t_resp=jnp.full((n,), BIG, jnp.int32),
            queue=jnp.full((max(sys.window, 2),), -1, jnp.int32),
            ptr=jnp.int32(0), mc_release=jnp.int32(0),
            dram_now=jnp.int32(0), hits=jnp.int32(0),
            served_n=jnp.int32(0), smc_fpga_cycles=jnp.int32(0),
            last_bank=jnp.int32(-1),
            faults={} if sys.faults is None else faultmod.init_fault_state(
                sys.faults, sys.geometry.n_banks))

    def to_host(self) -> dict:
        """Serializable nested dict of NumPy arrays (device -> host)."""
        return jax.tree_util.tree_map(np.asarray, dataclasses.asdict(self))

    @staticmethod
    def from_host(d: dict) -> "EmulatorState":
        """Inverse of :meth:`to_host`."""
        return EmulatorState(**jax.tree_util.tree_map(jnp.asarray, dict(d)))


_EMU_STATE_FIELDS = ("bank", "t_issue", "t_resp", "queue", "ptr",
                     "mc_release", "dram_now", "hits", "served_n",
                     "smc_fpga_cycles", "last_bank", "faults")
jax.tree_util.register_dataclass(
    EmulatorState, data_fields=list(_EMU_STATE_FIELDS), meta_fields=[])


def _issue_frontier(t_issue, t_resp, queue, kindj, delta, dep, ptr, W,
                    upto=4, gate=None):
    """Advance the in-order issue pointer by up to ``upto`` requests,
    pushing them into free hardware-queue slots. ``queue`` holds request
    indices (-1 = free); occupancy can never exceed the window W because
    issue is in-order with W outstanding.

    O(1) work per advance: point gathers plus predicated point-scatters
    (``arr.at[i].set(where(can, new, arr[i]))`` — a self-write when the
    advance is disabled), never full-length selects. ``gate`` (a traced
    bool, streaming freeze) ANDs into every advance predicate, so a
    gated-off call is the identity at the same O(1) cost."""
    N = t_issue.shape[0]
    for _ in range(upto):
        j = ptr
        jc = jnp.clip(j, 0, N - 1)
        prev_issue = jnp.where(j > 0, t_issue[jnp.clip(j - 1, 0, N - 1)], 0)
        base = prev_issue + delta[jc]
        wj = j - W
        win_known = (wj < 0) | (t_resp[jnp.clip(wj, 0, N - 1)] < BIG)
        win_t = jnp.where(wj >= 0, t_resp[jnp.clip(wj, 0, N - 1)] + 1, 0)
        dj = j - dep[jc]
        dep_on = dep[jc] > 0
        dep_known = (~dep_on) | (dj < 0) | (t_resp[jnp.clip(dj, 0, N - 1)] < BIG)
        dep_t = jnp.where(dep_on & (dj >= 0), t_resp[jnp.clip(dj, 0, N - 1)] + 1, 0)
        free = queue < 0
        slot = jnp.argmax(free).astype(jnp.int32)
        is_nop = kindj[jc] == 4  # NOP padding: resolve instantly, skip queue
        can = (j < N) & win_known & dep_known & (jnp.any(free) | is_nop)
        if gate is not None:
            can = can & gate
        t_new = jnp.maximum(jnp.maximum(base, win_t), dep_t)
        t_issue = t_issue.at[jc].set(jnp.where(can, t_new, t_issue[jc]))
        t_resp = t_resp.at[jc].set(jnp.where(can & is_nop, t_new, t_resp[jc]))
        queue = queue.at[slot].set(jnp.where(can & ~is_nop, jc, queue[slot]))
        ptr = jnp.where(can, ptr + 1, ptr)
    return t_issue, t_resp, queue, ptr


def _make_slot_body(kindj, bankj, rowj, deltaj, depj, sys: SystemConfig,
                    mode: str, bloom_words, bloom_k: int, bloom_m: int,
                    gate=None, policy_table=None, policy_cost=None):
    """Build the per-slot transition ``EmulatorState -> EmulatorState``
    over one set of trace arrays. This is THE slot body: the single-shot
    scan (:func:`_run_core`) and the streaming windows
    (:func:`_stream_step_core`) both scan exactly this function, which
    is what makes streamed results bit-identical to single-shot by
    construction. ``sys`` / ``mode`` / ``bloom_k`` / ``bloom_m`` are
    Python-level constants baked into the compiled program; every state
    update is a predicated point gather/scatter — O(Q)+O(1) work per
    slot (see module docstring).

    ``gate`` is the streaming freeze hook: a callable ``state -> traced
    bool``. When it returns False the step is the exact identity — the
    gate ANDs into the frontier-advance and service predicates, so every
    point-scatter self-writes and every scalar keeps its old value. This
    is deliberately NOT a ``lax.cond`` around the body: under ``vmap`` a
    batched-predicate cond lowers to both branches plus a select over
    the whole O(L) carry per slot, which would demote the linear-time
    core back to quadratic. Predicate-threading keeps frozen slots at
    the same O(Q)+O(1) cost as live ones (and ``gate=None`` compiles to
    exactly the pre-streaming program).

    ``policy_table`` is the PR-10 runtime-operand scheduling path: a
    packed ``[bucket + 1, 4]`` int32 program
    (:func:`smcprog.pack_program`) arriving as a traced OPERAND, so one
    executable serves any program of the bucket — and vmapping it over a
    stacked axis evaluates a whole policy grid per dispatch. It takes
    precedence over both ``sys.policy`` (the staged-constant path) and
    the legacy scheduler flag. Because the program content is unknown at
    trace time, its decision cost rides along as an operand too:
    ``policy_cost`` is an int32 ``[2]`` vector ``(counter_inc,
    smc_latency_proc)`` — the per-decision SMC cycle-counter increment
    and the nots-mode free-running decision latency, exactly the two
    numbers the staged path bakes in from ``sys.smc_cycles_per_decision``
    (derived host-side by :func:`_policy_cost_pair`, so the int
    arithmetic is bit-identical)."""
    N = kindj.shape[0]
    t = sys.timing
    geo = sys.geometry
    W = sys.window
    frfcfs = sys.scheduler == "frfcfs"
    policy = sys.policy
    fm = sys.faults
    use_bloom = bloom_words is not None

    # proc cycles per DRAM tick, fixed-point /FP
    scale_num = jnp.int32(round((sys.proc_per_tick_fpga if mode == "nots"
                                 else sys.proc_per_tick_emu) * FP))
    # per-decision MC occupancy (decision *rate*) and per-response latency:
    # ts models the emulated HW MC; nots free-runs against the real SMC
    mc_lat = jnp.int32(0 if mode == "nots" else sys.hwmc_latency_proc)
    if policy_table is not None:
        # runtime-operand policy: SMC cost is per-policy data, not a
        # staged constant (ts-mode issue rate models the emulated HW MC
        # and stays policy-independent, exactly as in the staged path)
        smc_lat = policy_cost[1]
        mc_issue = smc_lat if mode == "nots" else jnp.int32(sys.hwmc_issue_proc)
        vis_slack = smc_lat if mode == "nots" else jnp.int32(0)
        counter_inc = policy_cost[0]
    else:
        mc_issue = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                             else sys.hwmc_issue_proc)
        # a slow SMC batches up whatever arrived while it was busy (nots)
        vis_slack = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                              else 0)
        counter_inc = sys.smc_cycles_per_decision + sys.smc_transfer_cycles
    Q = max(W, 2)

    def step(st: EmulatorState) -> EmulatorState:
        live = None if gate is None else gate(st)
        t_issue, t_resp, queue, ptr = _issue_frontier(
            st.t_issue, st.t_resp, st.queue, kindj, deltaj, depj, st.ptr, W,
            gate=live)

        # gather queued requests (O(Q), not O(N))
        qvalid = queue >= 0
        qidx = jnp.clip(queue, 0, N - 1)
        q_t = jnp.where(qvalid, t_issue[qidx], BIG)
        q_bank = bankj[qidx]
        q_row = rowj[qidx]

        cutoff = st.mc_release + vis_slack
        visible = qvalid & (q_t <= cutoff)
        do = jnp.any(visible)
        if live is not None:
            do = do & live

        # ---- scheduling decision (int32-safe two-level argmin) ----
        open_rows = st.bank["open_row"]
        hit_now = open_rows[q_bank] == q_row
        mit = None
        if policy_table is not None:
            # runtime-operand path: the table-driven VM interprets the
            # packed program operand (one executable per length bucket)
            qslot, mit = smcprog.select_slot_table(policy_table, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                st.bank["ready"], st.dram_now, st.last_bank,
                geo.n_banks, Q, fault_hct=st.faults.get("hct"),
                fault_seed=0 if fm is None else fm.seed), visible)
        elif policy is not None:
            # software-defined path: the policy VM stages the program's
            # instruction table into branchless O(Q) vector ops here
            qslot, mit = smcprog.select_slot(policy, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                st.bank["ready"], st.dram_now, st.last_bank,
                geo.n_banks, Q, fault_hct=st.faults.get("hct"),
                fault_seed=0 if fm is None else fm.seed), visible)
        else:
            key_all = jnp.where(visible, q_t, BIG)
            key_hit = jnp.where(visible & hit_now, q_t, BIG)
            slot_hit = jnp.argmin(key_hit).astype(jnp.int32)
            slot_old = jnp.argmin(key_all).astype(jnp.int32)
            use_hit = frfcfs & jnp.any(visible & hit_now)
            qslot = jnp.where(use_hit, slot_hit, slot_old)
        pick = qidx[qslot]

        # ---- DRAM service (command-batch executor) ----
        # decision happens when the MC is free AND the request has arrived
        decision_t = jnp.maximum(t_issue[pick], st.mc_release)
        dram_req_t = jnp.maximum(st.dram_now,
                                 _mul_div(decision_t, FP, jnp.maximum(scale_num, 1)))
        trcd_eff = jnp.int32(t.tRCD)
        if use_bloom:
            gid = (bankj[pick] * geo.n_rows + rowj[pick]).astype(jnp.uint32)
            weakp = bloom_probe_jnp(bloom_words, bloom_m, bloom_k, gid[None])[0]
            trcd_eff = jnp.where(weakp, jnp.int32(t.tRCD), jnp.int32(t.tRCD_reduced))
        nbs, t_done, hit = dram.service_request(
            st.bank, t, kindj[pick], bankj[pick], rowj[pick],
            dram_req_t, trcd_eff)

        # ---- time scaling: response consume-tag in modeled proc cycles.
        # t_done is absolute DRAM time; decisions pipeline at mc_issue rate
        # while each response additionally carries the MC pipeline latency.
        resp_t = _mul_div(t_done, scale_num, FP) + mc_lat
        resp_t = jnp.maximum(resp_t, decision_t + mc_issue)

        # bank state advances only at index b: merge the served bank's row
        # of the transition (plus the channel scalars) as predicated point
        # writes instead of whole-array selects
        b = bankj[pick]
        bs = st.bank
        bank = {
            "open_row": bs["open_row"].at[b].set(
                jnp.where(do, nbs["open_row"][b], bs["open_row"][b])),
            "ready": bs["ready"].at[b].set(
                jnp.where(do, nbs["ready"][b], bs["ready"][b])),
            "act_at": bs["act_at"].at[b].set(
                jnp.where(do, nbs["act_at"][b], bs["act_at"][b])),
            "bus_busy": jnp.where(do, nbs["bus_busy"], bs["bus_busy"]),
            "refs_done": jnp.where(do, nbs["refs_done"], bs["refs_done"]),
        }
        fstate = st.faults
        if fm is not None:
            # fault hook: advance the error model for the served request
            # and charge any fired neighbor refresh to the bank. Gated
            # at the Python level — fm=None stages not one extra op.
            fstate, extra = faultmod.apply_slot(
                fm, geo.n_rows, t.tREFI, dram.neighbor_refresh_ticks(t),
                fstate, do=do, hit=hit, bank=b, row=rowj[pick],
                kind=kindj[pick], t_start=dram_req_t,
                refreshed=do & (nbs["refs_done"] != bs["refs_done"]),
                mitigate=mit)
            bank["ready"] = bank["ready"].at[b].add(extra)
        t_resp = t_resp.at[pick].set(jnp.where(do, resp_t, t_resp[pick]))
        queue = queue.at[qslot].set(jnp.where(do, -1, queue[qslot]))
        # MC busy until the next decision slot; idle hop to the next
        # arrival when nothing is visible — but only when something is
        # queued: hopping on an empty queue (mid-trace NOP run) would
        # saturate the counter to BIG-1 and poison every later response
        # (the pre-PR-4 idle-hop quirk)
        nxt = jnp.min(q_t)
        may_hop = jnp.any(qvalid)
        if live is not None:  # frozen slots must not idle-hop either
            may_hop = may_hop & live
        idle = jnp.where(
            may_hop,
            jnp.maximum(st.mc_release, jnp.minimum(nxt, BIG - 1)),
            st.mc_release)
        return EmulatorState(
            bank=bank, t_issue=t_issue, t_resp=t_resp, queue=queue, ptr=ptr,
            mc_release=jnp.where(
                do, jnp.maximum(st.mc_release, decision_t + mc_issue), idle),
            dram_now=jnp.where(do, jnp.maximum(st.dram_now, dram_req_t),
                               st.dram_now),
            hits=st.hits + jnp.where(do & hit, 1, 0),
            served_n=st.served_n + jnp.where(do, 1, 0),
            smc_fpga_cycles=st.smc_fpga_cycles + jnp.where(
                do, counter_inc, 0),
            last_bank=jnp.where(do, bankj[pick], st.last_bank),
            faults=fstate)

    return step


def _run_core(kind, bank, row, delta, dep, sys: SystemConfig, mode: str,
              bloom_words, bloom_k: int, bloom_m: int,
              slots: Optional[int] = None,
              policy_table=None, policy_cost=None):
    """One trace's single-shot scan: a fresh :class:`EmulatorState`
    driven through the shared slot body (:func:`_make_slot_body`) for
    the ``slots`` budget. Pure traceable function (jit/vmap applied by
    the compile cache below). ``policy_table`` / ``policy_cost`` are the
    runtime-operand policy inputs (see :func:`_make_slot_body`)."""
    N = kind.shape[0]
    W = sys.window
    step = _make_slot_body(kind, bank, row, delta, dep, sys, mode,
                           bloom_words, bloom_k, bloom_m,
                           policy_table=policy_table,
                           policy_cost=policy_cost)
    length = (2 * N + 4) if slots is None else slots
    state, _ = jax.lax.scan(lambda st, _: (step(st), None),
                            EmulatorState.init(N, sys), None, length=length)
    # trailing frontier pass so post-memory compute counts
    t_issue, _, _, ptr = _issue_frontier(
        state.t_issue, state.t_resp, state.queue,
        kind, delta, dep, state.ptr, W, upto=8)
    valid = kind != NOP
    served_mask = state.t_resp < BIG
    last_resp = jnp.max(jnp.where(valid & served_mask, state.t_resp, 0))
    last_issue = jnp.max(jnp.where(valid, t_issue, 0))
    out = {
        "exec_cycles": jnp.maximum(last_resp, last_issue),
        "row_hits": state.hits,
        "served": state.served_n,
        "dram_ticks": state.dram_now,
        "smc_fpga_cycles": state.smc_fpga_cycles,
        "t_resp": state.t_resp,
        "t_issue": t_issue,
    }
    if sys.faults is not None:
        out.update(faultmod.fault_result_fields(state.faults))
    return out


# ---------------------------------------------------------------------------
# Reference engine: the pre-optimization core. O(bucket) work per slot
# (full-length predicated selects), uniform 2*bucket+4 budget. Kept ONLY
# to pin bit-exactness (tests/test_property.py) and to measure the
# steady-state speedup (benchmarks --section sim_speed). Do not use for
# new work. Semantic changes are forbidden EXCEPT the ones the fast core
# must stay bit-identical under: the PR-4 policy-VM branch, the
# last_bank carry it reads, and the idle-hop empty-queue fix — all
# mirrored line-for-line from _run_core.
# ---------------------------------------------------------------------------


def _issue_frontier_ref(t_issue, t_resp, queue, kindj, delta, dep, ptr, W,
                        upto=4):
    N = t_issue.shape[0]
    for _ in range(upto):
        j = ptr
        jc = jnp.clip(j, 0, N - 1)
        prev_issue = jnp.where(j > 0, t_issue[jnp.clip(j - 1, 0, N - 1)], 0)
        base = prev_issue + delta[jc]
        wj = j - W
        win_known = (wj < 0) | (t_resp[jnp.clip(wj, 0, N - 1)] < BIG)
        win_t = jnp.where(wj >= 0, t_resp[jnp.clip(wj, 0, N - 1)] + 1, 0)
        dj = j - dep[jc]
        dep_on = dep[jc] > 0
        dep_known = (~dep_on) | (dj < 0) | (t_resp[jnp.clip(dj, 0, N - 1)] < BIG)
        dep_t = jnp.where(dep_on & (dj >= 0), t_resp[jnp.clip(dj, 0, N - 1)] + 1, 0)
        free = queue < 0
        slot = jnp.argmax(free).astype(jnp.int32)
        is_nop = kindj[jc] == 4
        can = (j < N) & win_known & dep_known & (jnp.any(free) | is_nop)
        t_new = jnp.maximum(jnp.maximum(base, win_t), dep_t)
        t_issue = jnp.where(can, t_issue.at[jc].set(t_new), t_issue)
        t_resp = jnp.where(can & is_nop, t_resp.at[jc].set(t_new), t_resp)
        queue = jnp.where(can & ~is_nop, queue.at[slot].set(jc), queue)
        ptr = jnp.where(can, ptr + 1, ptr)
    return t_issue, t_resp, queue, ptr


def _run_core_ref(kind, bank, row, delta, dep, sys: SystemConfig, mode: str,
                  bloom_words, bloom_k: int, bloom_m: int,
                  policy_table=None, policy_cost=None):
    N = kind.shape[0]
    t = sys.timing
    geo = sys.geometry
    W = sys.window
    frfcfs = sys.scheduler == "frfcfs"
    policy = sys.policy
    fm = sys.faults
    use_bloom = bloom_words is not None

    scale_num = jnp.int32(round((sys.proc_per_tick_fpga if mode == "nots"
                                 else sys.proc_per_tick_emu) * FP))
    mc_lat = jnp.int32(0 if mode == "nots" else sys.hwmc_latency_proc)
    if policy_table is not None:
        # runtime-operand policy cost, mirrored from _make_slot_body
        smc_lat = policy_cost[1]
        mc_issue = smc_lat if mode == "nots" else jnp.int32(sys.hwmc_issue_proc)
        vis_slack = smc_lat if mode == "nots" else jnp.int32(0)
        counter_inc = policy_cost[0]
    else:
        mc_issue = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                             else sys.hwmc_issue_proc)
        vis_slack = jnp.int32(sys.smc_latency_fpga_proc if mode == "nots"
                              else 0)
        counter_inc = sys.smc_cycles_per_decision + sys.smc_transfer_cycles

    Q = max(W, 2)
    state = {
        "bank": dram.init_bank_state(geo),
        "t_issue": jnp.zeros((N,), jnp.int32),
        "t_resp": jnp.full((N,), BIG, jnp.int32),
        "queue": jnp.full((Q,), -1, jnp.int32),
        "ptr": jnp.int32(0),
        "mc_release": jnp.int32(0),
        "dram_now": jnp.int32(0),
        "hits": jnp.int32(0),
        "served_n": jnp.int32(0),
        "smc_fpga_cycles": jnp.int32(0),
        "last_bank": jnp.int32(-1),
    }
    if fm is not None:
        state["faults"] = faultmod.init_fault_state(fm, geo.n_banks)

    kindj, bankj, rowj, deltaj, depj = kind, bank, row, delta, dep

    def slot(state, _):
        t_issue, t_resp = state["t_issue"], state["t_resp"]
        t_issue, t_resp, queue, ptr = _issue_frontier_ref(
            t_issue, t_resp, state["queue"], kindj, deltaj, depj,
            state["ptr"], W)

        qvalid = queue >= 0
        qidx = jnp.clip(queue, 0, N - 1)
        q_t = jnp.where(qvalid, t_issue[qidx], BIG)
        q_bank = bankj[qidx]
        q_row = rowj[qidx]

        cutoff = state["mc_release"] + vis_slack
        visible = qvalid & (q_t <= cutoff)
        do = jnp.any(visible)

        open_rows = state["bank"]["open_row"]
        hit_now = open_rows[q_bank] == q_row
        mit = None
        if policy_table is not None:
            # runtime-operand branch mirrored from _make_slot_body
            qslot, mit = smcprog.select_slot_table(policy_table, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                state["bank"]["ready"], state["dram_now"],
                state["last_bank"], geo.n_banks, Q,
                fault_hct=state.get("faults", {}).get("hct"),
                fault_seed=0 if fm is None else fm.seed), visible)
        elif policy is not None:
            qslot, mit = smcprog.select_slot(policy, _policy_env(
                q_t, q_bank, q_row, qidx, visible, hit_now, kindj,
                state["bank"]["ready"], state["dram_now"],
                state["last_bank"], geo.n_banks, Q,
                fault_hct=state.get("faults", {}).get("hct"),
                fault_seed=0 if fm is None else fm.seed), visible)
        else:
            key_all = jnp.where(visible, q_t, BIG)
            key_hit = jnp.where(visible & hit_now, q_t, BIG)
            slot_hit = jnp.argmin(key_hit).astype(jnp.int32)
            slot_old = jnp.argmin(key_all).astype(jnp.int32)
            use_hit = frfcfs & jnp.any(visible & hit_now)
            qslot = jnp.where(use_hit, slot_hit, slot_old)
        pick = qidx[qslot]

        decision_t = jnp.maximum(t_issue[pick], state["mc_release"])
        dram_req_t = jnp.maximum(state["dram_now"],
                                 _mul_div(decision_t, FP, jnp.maximum(scale_num, 1)))
        trcd_eff = jnp.int32(t.tRCD)
        if use_bloom:
            gid = (bankj[pick] * geo.n_rows + rowj[pick]).astype(jnp.uint32)
            weakp = bloom_probe_jnp(bloom_words, bloom_m, bloom_k, gid[None])[0]
            trcd_eff = jnp.where(weakp, jnp.int32(t.tRCD), jnp.int32(t.tRCD_reduced))
        nbs, t_done, hit = dram.service_request(
            state["bank"], t, kindj[pick], bankj[pick], rowj[pick],
            dram_req_t, trcd_eff)

        resp_t = _mul_div(t_done, scale_num, FP) + mc_lat
        resp_t = jnp.maximum(resp_t, decision_t + mc_issue)

        state = dict(state)
        old_refs = state["bank"]["refs_done"]
        state["bank"] = jax.tree_util.tree_map(
            lambda a, b: jnp.where(do, b, a), state["bank"], nbs)
        if fm is not None:
            # fault hook mirrored from _make_slot_body (shared apply_slot
            # — the semantics live in repro.core.faults, not here)
            bsel = bankj[pick]
            fstate, extra = faultmod.apply_slot(
                fm, geo.n_rows, t.tREFI, dram.neighbor_refresh_ticks(t),
                state["faults"], do=do, hit=hit, bank=bsel,
                row=rowj[pick], kind=kindj[pick], t_start=dram_req_t,
                refreshed=do & (nbs["refs_done"] != old_refs),
                mitigate=mit)
            state["faults"] = fstate
            state["bank"]["ready"] = state["bank"]["ready"].at[bsel].add(extra)
        state["t_resp"] = jnp.where(do, t_resp.at[pick].set(resp_t), t_resp)
        queue = jnp.where(do, queue.at[qslot].set(-1), queue)
        state["dram_now"] = jnp.where(do, jnp.maximum(state["dram_now"], dram_req_t),
                                      state["dram_now"])
        state["hits"] = state["hits"] + jnp.where(do & hit, 1, 0)
        state["served_n"] = state["served_n"] + jnp.where(do, 1, 0)
        state["smc_fpga_cycles"] = state["smc_fpga_cycles"] + jnp.where(
            do, counter_inc, 0)
        state["last_bank"] = jnp.where(do, bankj[pick], state["last_bank"])
        # idle-hop fix mirrored from _run_core: never hop on an empty queue
        nxt = jnp.min(q_t)
        idle = jnp.where(
            jnp.any(qvalid),
            jnp.maximum(state["mc_release"], jnp.minimum(nxt, BIG - 1)),
            state["mc_release"])
        state["mc_release"] = jnp.where(
            do, jnp.maximum(state["mc_release"], decision_t + mc_issue), idle)
        state["t_issue"], state["queue"], state["ptr"] = t_issue, queue, ptr
        return state, None

    state, _ = jax.lax.scan(slot, state, None, length=2 * N + 4)
    t_issue, _, _, ptr = _issue_frontier_ref(
        state["t_issue"], state["t_resp"], state["queue"],
        kindj, deltaj, depj, state["ptr"], W, upto=8)
    valid = kindj != NOP
    served_mask = state["t_resp"] < BIG
    last_resp = jnp.max(jnp.where(valid & served_mask, state["t_resp"], 0))
    last_issue = jnp.max(jnp.where(valid, t_issue, 0))
    out = {
        "exec_cycles": jnp.maximum(last_resp, last_issue),
        "row_hits": state["hits"],
        "served": state["served_n"],
        "dram_ticks": state["dram_now"],
        "smc_fpga_cycles": state["smc_fpga_cycles"],
        "t_resp": state["t_resp"],
        "t_issue": t_issue,
    }
    if fm is not None:
        out.update(faultmod.fault_result_fields(state["faults"]))
    return out


def pad_trace(tr: Trace, n: int) -> Trace:
    """Pad with NOPs to length n (keeps jit caches warm across sizes)."""
    k = n - tr.n
    if k < 0:  # ValueError, not assert: survives python -O
        raise ValueError(
            f"cannot pad a trace of length {tr.n} down to {n}: the "
            f"target must be >= the trace length")
    z = np.zeros(k, np.int32)
    return Trace(kind=np.concatenate([tr.kind, z + 4]),
                 bank=np.concatenate([tr.bank, z]),
                 row=np.concatenate([tr.row, z]),
                 delta=np.concatenate([tr.delta, z]),
                 dep=np.concatenate([tr.dep, z]))


def _bucket(n: int) -> int:
    b = 32
    while b < n:
        b *= 2
    return b


def slot_budget(bucket: int, n_real: int) -> int:
    """Exact scan-slot budget for a batch group padded to ``bucket``
    whose largest trace has ``n_real`` non-NOP requests:

        2 * Rq + ceil((bucket - Rq) / 4) + 4

    with Rq = n_real rounded up to a ``max(bucket // 4, 8)`` granule
    (capped at bucket). Real requests cost at most 2 slots each (idle
    hop + serve, with issue piggybacking on earlier slots); NOPs resolve
    4 per slot in the frontier and never enter the queue. The budget is
    monotone in n_real, so the group max covers every member; surplus
    slots are no-ops, keeping results bit-identical to any larger
    budget (2*bucket+4 degenerate case included)."""
    g = max(bucket // 4, 8)
    rq = min(bucket, -(-n_real // g) * g)
    return 2 * rq + (bucket - rq + 3) // 4 + 4


def _batch_bucket(b: int) -> int:
    """Pad the batch axis to a power of two so sweeps of nearby sizes
    share one executable (padding rows are all-NOP traces)."""
    p = 1
    while p < b:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Batched campaigns: module-level compile cache over vmapped executables.
# LRU-bounded (``REPRO_EMU_CACHE_CAP`` / :func:`set_cache_capacity`) so an
# unbounded sweep of distinct compile keys cannot retain every executable
# it ever built; evictions are counted in :func:`cache_stats`. A second
# *process* re-running the same sweep skips the XLA compile entirely when
# the persistent on-disk cache is enabled
# (:func:`repro.utils.jax_compat.enable_persistent_compile_cache`).
# ---------------------------------------------------------------------------

_COMPILE_CACHE: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_CACHE_LOCK = threading.Lock()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_CACHE_CAP = max(1, executor._env_int("REPRO_EMU_CACHE_CAP", 128))

# batch-axis device sharding of run_many executables:
#   'auto'  — shard_map over local devices when >1 is present and the
#             padded batch axis divides across them; plain vmap otherwise
#   'off'   — never wrap in shard_map
#   'force' — always wrap, even over a single-device mesh (exercises the
#             shard_map code path on 1-device hosts; bit-identical)
_SHARD_MODES = ("auto", "off", "force")
_SHARD_MODE = os.environ.get("REPRO_EXEC_SHARD", "auto")


def set_sharding(mode: str) -> str:
    """Set the batch-axis sharding mode ('auto' | 'off' | 'force');
    returns the previous mode. Sharded and unsharded executables live
    under distinct cache keys, so toggling never returns a stale fn."""
    global _SHARD_MODE
    if mode not in _SHARD_MODES:
        raise ValueError(
            f"sharding mode must be one of {_SHARD_MODES}, got {mode!r}")
    old, _SHARD_MODE = _SHARD_MODE, mode
    return old


def _shard_count(batch: int) -> int:
    """Number of mesh devices for a padded batch axis of ``batch``:
    0 = no shard_map wrapper; >= 1 = wrap over that many devices (1 only
    under 'force'). The padded batch is a power of two, so the largest
    power-of-two device count that divides it is used."""
    if _SHARD_MODE == "off":
        return 0
    ndev = jax.local_device_count()
    n = 1
    while n * 2 <= ndev and batch % (n * 2) == 0:
        n *= 2
    if n == 1 and _SHARD_MODE != "force":
        return 0
    return n


def _norm_mode(mode: str) -> str:
    """'reference' compiles to the exact 'ts' program — that coincidence
    IS the paper's time-scaling claim — so they share one executable."""
    return "ts" if mode == "reference" else mode


def _is_bloom_triple(b) -> bool:
    """One (words_u32, k, m_bits) filter: words array + two scalars (as
    opposed to a per-trace sequence of such triples)."""
    return (len(b) == 3 and not isinstance(b[0], (tuple, list))
            and np.ndim(b[1]) == 0 and np.ndim(b[2]) == 0)


def _bloom_shape(blooms) -> Optional[tuple]:
    """Shape signature of a blooms argument: None, one shared (words, k,
    m_bits) filter, or a per-trace sequence of identically-shaped
    triples — shared-vs-stacked decided by content (like
    :func:`_normalize_blooms`), not container type."""
    if blooms is None:
        return None
    if _is_bloom_triple(blooms):
        return ("shared", int(np.asarray(blooms[0]).shape[0]),
                blooms[1], blooms[2])
    b0 = tuple(blooms[0])
    return ("stacked", int(np.asarray(b0[0]).shape[0]), b0[1], b0[2])


def _policy_rt_sys(sys: SystemConfig) -> SystemConfig:
    """Normalize a config for the runtime-operand policy path: the
    staged policy, the legacy scheduler flag, and the per-decision SMC
    cost are all dead in the traced program there (the table and its
    cost arrive as operands), so they are scrubbed from the compile /
    group key — configs differing only in those fields share ONE
    executable, which is the whole point of the policy axis."""
    return dataclasses.replace(sys, policy=None, scheduler="frfcfs",
                               smc_cycles_per_decision=0)


def _policy_cost_pair(sys: SystemConfig, cpd: int) -> tuple:
    """Host-side derivation of the runtime ``policy_cost`` operand for a
    policy whose ``smc_cycles_per_decision`` is ``cpd``: ``(counter_inc,
    smc_latency_proc)``, via the exact same Python-int / float rounding
    the staged path bakes into its constants (``smc_latency_fpga_proc``
    does float64 math — it must happen HERE, not in traced int32 ops,
    for bit-identity)."""
    csys = dataclasses.replace(sys, smc_cycles_per_decision=int(cpd))
    return (int(cpd) + int(sys.smc_transfer_cycles),
            int(csys.smc_latency_fpga_proc))


def _policy_shape(policy) -> Optional[tuple]:
    """Key element for the runtime policy axis: None (no policy
    operand) or ``("policy", table_bucket)`` — the padded table LENGTH
    is the only traced-shape property; content never reaches the key."""
    if policy is None:
        return None
    if isinstance(policy, smcprog.PolicyProgram):
        return ("policy", smcprog.table_bucket(policy.n_ops))
    return ("policy", int(policy))


def group_key(n: int, sys: SystemConfig, mode: str, blooms,
              policy=None) -> tuple:
    """Grouping key for one trace-length-n point: everything a batched
    executable is specialized on EXCEPT the batch axis and slot budget,
    which only exist once a group is assembled (run_many derives them
    per group). One source of truth with :func:`compile_key` for the
    bucket / mode / bloom-shape normalization — used by
    :class:`repro.core.campaign.Campaign`.

    ``policy`` (a :class:`smcprog.PolicyProgram` or a table bucket int)
    selects the runtime-operand policy axis: the key then normalizes
    ``sys`` (:func:`_policy_rt_sys`) and appends the table-length
    bucket, so any number of same-bucket programs — whatever their
    content or derived cost — land in ONE group."""
    if policy is None:
        return (_bucket(n), sys, _norm_mode(mode), _bloom_shape(blooms))
    return (_bucket(n), _policy_rt_sys(sys), _norm_mode(mode),
            _bloom_shape(blooms), _policy_shape(policy))


def compile_key(bucket: int, batch: int, sys: SystemConfig, mode: str,
                blooms, slots: Optional[int] = None,
                policy_bucket: Optional[int] = None) -> tuple:
    """Cache key for one batched executable (see :func:`_bloom_shape`
    for the ``blooms`` normalization). ``slots`` is the group's
    :func:`slot_budget` (None for the uniform-budget reference
    engine). ``sys`` carries the staged policy program, which hashes by
    instruction-table content (digest semantics): same-content programs
    share one executable, distinct programs fork the key — so a staged
    policy grid runs one batched dispatch per program.
    ``policy_bucket`` instead selects the runtime-operand policy axis
    (callers pass a :func:`_policy_rt_sys`-normalized ``sys`` with it):
    only the padded table LENGTH forks the key, so a whole grid of
    same-bucket programs shares one executable."""
    return (bucket, slots, _batch_bucket(batch), sys, _norm_mode(mode),
            _bloom_shape(blooms),
            None if policy_bucket is None else _policy_shape(policy_bucket))


def cache_stats() -> dict:
    """Executable-cache counters since the last :func:`cache_clear`:
    ``hits`` / ``misses`` (misses == in-process compiles) over
    :func:`run_many` lookups, ``evictions`` (LRU drops past
    ``capacity``), plus current ``size`` / ``capacity`` and the derived
    ``lookups`` (= hits + misses). ``persistent`` mirrors the on-disk
    XLA cache counters when
    :func:`repro.utils.jax_compat.enable_persistent_compile_cache` is
    active (all-zero otherwise).

    The snapshot is CONSISTENT: every LRU field is read in one
    ``_CACHE_LOCK`` region — the same lock every writer
    (``_batched_fn`` / ``_stream_fn`` lookups, ``set_cache_capacity``
    shrinks, ``cache_clear``) holds across its whole update — so a
    concurrent reader (a sweep-service stats poll while dispatchers
    resolve executables) can never observe a torn view: ``lookups ==
    hits + misses``, ``size <= capacity``, and
    ``size == misses - evictions`` (counters monotone between clears)
    all hold in any returned dict, which
    ``tests/test_service.py::test_cache_stats_consistent_under_threads``
    hammers from threads. Only ``persistent`` is sampled outside the
    lock — it belongs to jax's process-global cache, not this LRU."""
    from repro.utils import jax_compat
    with _CACHE_LOCK:
        out = dict(_CACHE_STATS)
        out["size"] = len(_COMPILE_CACHE)
        out["capacity"] = _CACHE_CAP
        out["lookups"] = out["hits"] + out["misses"]
    out["persistent"] = jax_compat.persistent_cache_stats()
    return out


def cache_clear() -> None:
    """Drop every cached executable and zero ALL counters (hits,
    misses, and the eviction counter added with the LRU bound)."""
    with _CACHE_LOCK:
        _COMPILE_CACHE.clear()
        for k in _CACHE_STATS:
            _CACHE_STATS[k] = 0


def set_cache_capacity(n: int) -> int:
    """Bound the in-memory executable cache to ``n`` entries (LRU);
    returns the previous capacity. Shrinking evicts immediately."""
    global _CACHE_CAP
    if n < 1:
        raise ValueError(f"cache capacity must be >= 1, got {n}")
    with _CACHE_LOCK:
        old, _CACHE_CAP = _CACHE_CAP, n
        while len(_COMPILE_CACHE) > _CACHE_CAP:
            _COMPILE_CACHE.popitem(last=False)
            _CACHE_STATS["evictions"] += 1
    return old


def _shard_wrap(fn, nshards: int, bshape, pshape=None):
    """Wrap a batched runner in ``shard_map`` over ``nshards`` local
    devices on the (leading) batch axis. Trace arrays shard; a shared
    Bloom filter replicates; stacked per-trace filters shard; stacked
    policy tables/costs (the runtime policy axis) shard. Inside
    each shard the wrapped fn sees a ``batch/nshards`` slice and vmaps
    over it exactly as in the unsharded path, so results concatenate to
    the bit-identical full batch."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.utils import jax_compat
    mesh = Mesh(np.array(jax.local_devices()[:nshards]), ("batch",))
    spec = P("batch")
    if bshape is None:
        in_specs = (spec,) * 5
    else:
        in_specs = (spec,) * 5 + (spec if bshape[0] == "stacked" else P(),)
    if pshape is not None:
        in_specs = in_specs + (spec, spec)
    return jax_compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=spec,
                                **jax_compat.shard_map_kwargs())


class _CachedRunner:
    """One cached executable: a lazily-compiled jitted runner plus the
    argument shapes its compile key fixes.

    :meth:`prime` compiles it NOW, on the calling thread, by running an
    all-zeros dummy batch (all-NOP-free zero reads; one scan execution,
    noise next to the compile). ``prepare_tasks`` primes every resolved
    runner in group order on the caller's thread before any executor
    worker starts, which buys two properties the lazy first-call would
    lose: (a) tracing/lowering interleaved across worker threads makes
    jax's uid counters — and so the emitted StableHLO bytes and the
    persistent on-disk cache key — nondeterministic across processes
    (observed: one fresh disk entry per run); (b) only the *warmed* C++
    jit fast path executes synchronously on the calling thread under
    the inline CPU runtime — an unwarmed call (and the AOT
    ``Lowered.compile()(...)`` path) enqueues onto the device's single
    execute thread, which silently serializes the overlapped groups."""

    __slots__ = ("jitted", "avals", "primed")

    def __init__(self, jitted, avals):
        self.jitted = jitted
        self.avals = avals
        self.primed = False

    def prime(self) -> "_CachedRunner":
        # donation warning noise is suppressed by the module-level
        # filter (a per-call catch_warnings here would race: it mutates
        # process-global filter state while workers may be executing)
        if not self.primed:
            # an aval entry is (shape, dtype) for an all-zeros dummy, or
            # a zero-arg callable building a structured dummy (the
            # streaming runners pass their initial StreamState this way)
            self.jitted(*(a() if callable(a) else jnp.zeros(a[0], a[1])
                          for a in self.avals))
            self.primed = True
        return self

    def __call__(self, *args):
        return self.jitted(*args)


def _batched_fn(key: tuple, ref: bool = False):
    """Jitted vmapped runner for one compile key; built once per key,
    LRU-retained up to the cache capacity (a :class:`_CachedRunner`,
    compiled on first :meth:`~_CachedRunner.prime` or call). ``ref=True``
    builds the pre-optimization reference engine (no slot budget, no
    donation) on a separate cache entry. When batch-axis sharding
    applies (see :func:`set_sharding`), the runner is shard_mapped over
    the local devices — sharded and unsharded variants fork the cache
    key, so counter semantics are unchanged for a fixed device
    topology."""
    batch = key[2]
    nshards = _shard_count(batch)
    ckey = ("ref" if ref else "fast", nshards, key)
    # get-or-create is atomic: the lock is held across the whole build
    # (cheap — jit wrapping and Mesh construction; the XLA compile is
    # deferred to prime()/first call), so two threads racing on one key
    # can neither duplicate the entry nor skew the hit/miss counters
    with _CACHE_LOCK:
        fn = _COMPILE_CACHE.get(ckey)
        if fn is not None:
            _CACHE_STATS["hits"] += 1
            _COMPILE_CACHE.move_to_end(ckey)
            return fn
        _CACHE_STATS["misses"] += 1
        runner = _build_runner(key, ref, nshards)
        _COMPILE_CACHE[ckey] = runner
        while len(_COMPILE_CACHE) > _CACHE_CAP:
            _COMPILE_CACHE.popitem(last=False)
            _CACHE_STATS["evictions"] += 1
    return runner


def _build_runner(key: tuple, ref: bool, nshards: int) -> "_CachedRunner":
    """Construct the (lazily-compiled) runner for one cache key.
    Argument order after the five trace arrays: the Bloom words (when
    the key has a bloom shape), then the stacked policy tables + cost
    pairs (when it has a policy shape) — tables/costs always ride the
    batch axis (axis 0), one program per batch row."""
    _, slots, batch, sys, mode, bshape, pshape = key
    core = _run_core_ref if ref else _run_core
    extra = {} if ref else {"slots": slots}
    has_bloom = bshape is not None
    has_pol = pshape is not None
    if has_bloom:
        stacked, _, bk, bm = bshape
        words_axis = 0 if stacked == "stacked" else None
    axes = (0,) * 5 + ((words_axis,) if has_bloom else ()) \
        + ((0, 0) if has_pol else ())

    def one(k, b, r, d, dp, *rest):
        i = 0
        bloom_args = (None, 0, 1)
        if has_bloom:
            bloom_args = (rest[0], bk, bm)
            i = 1
        pol = ({"policy_table": rest[i], "policy_cost": rest[i + 1]}
               if has_pol else {})
        return core(k, b, r, d, dp, sys, mode, *bloom_args, **extra, **pol)

    def fn(*args):
        return jax.vmap(one, in_axes=axes)(*args)

    if nshards:
        fn = _shard_wrap(fn, nshards, bshape, pshape)

    # trace arrays are freshly staged from host memory every call, so the
    # executable may reuse their buffers for its outputs (bloom words can
    # be caller-shared jnp arrays -> not donated); donation is best-effort
    # by design, so the inputs-not-aliased warning is pure noise
    jitted = jax.jit(fn) if ref else jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4))
    bucket, bb = key[0], _batch_bucket(batch)
    avals = [((bb, bucket), jnp.int32)] * 5
    if bshape is not None:
        wshape = (bshape[1],) if bshape[0] == "shared" else (bb, bshape[1])
        avals = avals + [(wshape, jnp.uint32)]
    if has_pol:
        avals = avals + [((bb, pshape[1] + 1, 4), jnp.int32),
                         ((bb, 2), jnp.int32)]
    return _CachedRunner(jitted, avals)


def _finalize(out_row: dict, padded: Trace, sys: SystemConfig,
              mode: str) -> dict:
    """Per-trace derived metrics — identical math to the original
    single-trace ``run`` so batched results stay drop-in compatible."""
    out = {kk: np.asarray(v) for kk, v in out_row.items()}
    out["exec_seconds"] = sys.cycles_to_seconds(out["exec_cycles"], mode)
    out["mode"] = mode
    out["n_requests"] = int((padded.kind != NOP).sum())
    lat = out["t_resp"] - out["t_issue"]
    ok = (padded.kind != NOP) & (out["t_resp"] < int(BIG))
    out["avg_load_latency_cycles"] = float(lat[ok].mean()) if ok.any() else 0.0
    if "flips" in out:  # fault model attached: flips per served request
        out["bit_error_rate"] = float(out["flips"]) / max(int(out["served"]), 1)
    return out


def _normalize_blooms(blooms, n: int):
    """blooms: None | one (words, k, m_bits) filter (any sequence type)
    | a per-trace sequence of identically-shaped filter triples. ->
    None | shared tuple | list of tuples (no mixed None: group
    upstream). Shared-vs-per-trace is decided by content, not container
    type, so a list-typed single filter still broadcasts."""
    if blooms is None:
        return None
    blooms = list(blooms)
    if _is_bloom_triple(blooms):
        return tuple(blooms)
    blooms = [tuple(b) for b in blooms]
    # real exceptions, not asserts: these guard public entry points
    # (run_many / run_stream_many / Campaign) and must survive python -O
    if len(blooms) != n:
        raise ValueError(
            f"per-trace blooms ({len(blooms)}) must match len(traces) ({n})")
    b0 = blooms[0]
    if not all(_is_bloom_triple(b) and b[1] == b0[1] and b[2] == b0[2]
               and np.asarray(b[0]).shape == np.asarray(b0[0]).shape
               for b in blooms):
        raise ValueError(
            "per-trace blooms must share (words-shape, k, m_bits); use "
            "Campaign to mix bloom/no-bloom points in one grid")
    return blooms


def check_mode(mode: str) -> str:
    """Validate one evaluation mode; a real ValueError (not an assert
    — asserts vanish under ``python -O``) carrying the offending value.
    Single source of truth for every mode guard (``run`` / ``run_many``
    / ``Campaign.add`` / ``Campaign.add_policy_grid``)."""
    if mode not in ("ts", "nots", "reference"):
        raise ValueError(
            f"mode must be one of ('ts', 'nots', 'reference'), got {mode!r}")
    return mode


def _check_modes(modes: Sequence[str], n: int) -> List[str]:
    modes = list(modes)
    if len(modes) != n:
        raise ValueError(
            f"per-trace modes ({len(modes)}) must match len(traces) ({n})")
    for m in modes:
        check_mode(m)
    return modes


def _normalize_policies(policies, policy_costs, sys: SystemConfig, n: int):
    """policies: None | per-trace sequence of PolicyProgram (the
    runtime policy axis — one program PER TRACE ROW; run the same trace
    against P programs by repeating it P times, which is what
    :func:`run_policies` does). policy_costs: None (every row keeps
    ``sys.smc_cycles_per_decision``, matching a staged
    ``dataclasses.replace(sys, policy=p)``) | per-trace sequence of
    smc_cycles_per_decision ints (pass ``p.smc_cycles()`` to match
    staged ``sys.with_policy(p)``). Returns None or (programs, costs)."""
    if policies is None:
        if policy_costs is not None:
            raise ValueError("policy_costs requires policies")
        return None
    policies = list(policies)
    if len(policies) != n:
        raise ValueError(
            f"per-trace policies ({len(policies)}) must match "
            f"len(traces) ({n})")
    for p in policies:
        if not isinstance(p, smcprog.PolicyProgram):
            raise TypeError(
                f"policies must be smcprog.PolicyProgram, got "
                f"{type(p).__name__}")
        p.validate()
    if policy_costs is None:
        costs = [int(sys.smc_cycles_per_decision)] * n
    else:
        costs = [int(c) for c in policy_costs]
        if len(costs) != n:
            raise ValueError(
                f"per-trace policy_costs ({len(costs)}) must match "
                f"len(traces) ({n})")
    return policies, costs


def prepare_tasks(traces: Sequence[Trace], sys: SystemConfig,
                  mode: Union[str, Sequence[str]], blooms,
                  results: List[Optional[dict]], ref: bool = False,
                  policies=None, policy_costs=None,
                  ) -> List[executor.GroupTask]:
    """Plan one :func:`run_many`-style call into executable
    :class:`repro.core.executor.GroupTask`s WITHOUT running them.

    Grouping, executable-cache resolution (``_batched_fn`` — so
    ``cache_stats`` counters settle deterministically on the caller's
    thread, in group order), and slot budgeting happen here; the
    host-side padding/stacking and the device dispatch are deferred
    into each task's ``pack``/``run``, which is what lets the
    campaign executor overlap group k+1's packing with group k's
    compute. Each task finalizes into its own ``results`` slots
    (``results`` must be a list of ``len(traces)`` Nones).

    With ``policies`` (see :func:`_normalize_policies`) each trace row
    carries its own packed program + cost pair down the batch axis —
    the runtime policy axis: grouping gains the table-length bucket,
    ``sys`` is key-normalized (:func:`_policy_rt_sys`), and one
    executable per (trace-bucket, mode, table-bucket) evaluates the
    whole grid, however many distinct programs it holds.
    """
    traces = list(traces)
    n = len(traces)
    modes = _check_modes([mode] * n if isinstance(mode, str) else mode, n)
    blooms = _normalize_blooms(blooms, n)
    pol = _normalize_policies(policies, policy_costs, sys, n)

    groups: dict = {}  # (bucket, normalized mode, table bucket) -> [idx]
    for i, tr in enumerate(traces):
        lb = None if pol is None else smcprog.table_bucket(pol[0][i].n_ops)
        groups.setdefault(
            (_bucket(tr.n), _norm_mode(modes[i]), lb), []).append(i)

    tasks: List[executor.GroupTask] = []
    for (bucket, gmode, lb), idxs in groups.items():
        slots = None if ref else slot_budget(
            bucket, max(traces[i].n_real for i in idxs))
        gsys = sys if lb is None else _policy_rt_sys(sys)
        key = compile_key(bucket, len(idxs), gsys, gmode, blooms, slots, lb)
        fn = _batched_fn(key, ref=ref).prime()

        def pack(idxs=idxs, bucket=bucket, lb=lb):
            padded = [pad_trace(traces[i], bucket) for i in idxs]
            bb = _batch_bucket(len(idxs))
            if bb > len(idxs):  # all-NOP filler rows, discarded below
                filler = Trace.of(np.full(bucket, 4), np.zeros(bucket),
                                  np.zeros(bucket), np.zeros(bucket))
                padded += [filler] * (bb - len(idxs))
            stacked = [jnp.asarray(np.stack([getattr(p, f) for p in padded]))
                       for f in ("kind", "bank", "row", "delta", "dep")]
            if blooms is None:
                args = tuple(stacked)
            elif isinstance(blooms, tuple):
                args = (*stacked, jnp.asarray(blooms[0]))
            else:
                words = np.stack([np.asarray(blooms[i][0]) for i in idxs])
                if bb > len(idxs):
                    words = np.concatenate(
                        [words, np.repeat(words[:1], bb - len(idxs), axis=0)])
                args = (*stacked, jnp.asarray(words))
            if lb is not None:
                tables = np.stack(
                    [smcprog.pack_program(pol[0][i], lb) for i in idxs])
                cost = np.asarray(
                    [_policy_cost_pair(sys, pol[1][i]) for i in idxs],
                    np.int32)
                if bb > len(idxs):  # filler rows repeat row 0 (discarded)
                    tables = np.concatenate(
                        [tables,
                         np.repeat(tables[:1], bb - len(idxs), axis=0)])
                    cost = np.concatenate(
                        [cost, np.repeat(cost[:1], bb - len(idxs), axis=0)])
                args = (*args, jnp.asarray(tables), jnp.asarray(cost))
            return args, padded

        def finalize(out, padded, idxs=idxs):
            for j, i in enumerate(idxs):
                row = {kk: v[j] for kk, v in out.items()}
                results[i] = _finalize(row, padded[j], sys, modes[i])

        ptag = "" if lb is None else f":pol{lb}"
        tasks.append(executor.GroupTask(
            fn=fn, pack=pack, finalize=finalize,
            label=f"b{bucket}x{len(idxs)}:{gmode}{ptag}",
            cost=(slots or 2 * bucket + 4) * _batch_bucket(len(idxs))))
    return tasks


def _execute_entry_point(tasks, serial) -> None:
    """Execute for the library entry points (run_many/run_stream_many):
    a single failed task re-raises its ORIGINAL exception — validation
    errors like a dep_max violation keep their type and message — and
    only a genuine multi-failure raises the executor's aggregate
    :class:`repro.core.executor.ExecutionError`. Campaign.run() goes
    through :func:`repro.core.executor.execute` directly and always
    sees the full failure records."""
    fails = executor.execute(tasks, serial=serial, raise_on_error=False)
    if fails:
        if len(fails) == 1:
            raise fails[0].error
        raise executor.ExecutionError(fails)


def _run_grouped(traces: Sequence[Trace], sys: SystemConfig,
                 mode: Union[str, Sequence[str]], blooms,
                 ref: bool, serial: Optional[bool] = None,
                 policies=None, policy_costs=None) -> List[dict]:
    """Shared grouped-execution path for :func:`run_many` (exact slot
    budgets) and :func:`run_ref_many` (uniform reference budgets):
    plan into group tasks, then execute — overlapped across the
    executor's worker pool when more than one group is present, or
    strictly in-order under ``serial=True``. Bit-identical either way
    (the executor only changes wall-clock interleaving)."""
    traces = list(traces)
    results: List[Optional[dict]] = [None] * len(traces)
    tasks = prepare_tasks(traces, sys, mode, blooms, results, ref=ref,
                          policies=policies, policy_costs=policy_costs)
    _execute_entry_point(tasks, serial)
    return results


def run_many(traces: Sequence[Trace], sys: SystemConfig,
             mode: Union[str, Sequence[str]] = "ts",
             blooms=None, serial: Optional[bool] = None,
             policies=None, policy_costs=None) -> List[dict]:
    """Evaluate many traces under one ``SystemConfig`` in batched calls.

    ``mode`` is one of 'ts' | 'nots' | 'reference', or a per-trace
    sequence of them. ``blooms`` is None, one shared ``(words, k,
    m_bits)`` tuple, or a per-trace list of identically-shaped tuples
    (stacked and vmapped alongside the traces).

    Traces are grouped by ``(length-bucket, mode)``; each group pads to
    its bucket, pads the batch axis to a power of two with all-NOP
    traces, computes its exact :func:`slot_budget` from the largest
    member, and executes as ONE vmapped, jit-cached call (trace buffers
    donated; batch axis sharded across local devices when present —
    see :func:`set_sharding`). Multi-group calls overlap host packing
    with device compute across the ``repro.core.executor`` worker pool;
    ``serial=True`` forces the in-order loop (bit-identical, for A/B).
    Returns one dict per input trace, in input order, bit-identical to
    ``run(trace, sys, mode, bloom)``.

    ``policies`` / ``policy_costs`` select the runtime policy axis: one
    :class:`smcprog.PolicyProgram` per trace row, packed into a stacked
    table operand so same-table-bucket rows share ONE executable
    regardless of program content (see :func:`_normalize_policies` for
    the cost semantics and :func:`run_policies` for the
    one-trace-many-programs convenience form). Bit-identical to
    attaching each program via ``sys.policy`` staged constants.
    """
    return _run_grouped(traces, sys, mode, blooms, ref=False, serial=serial,
                        policies=policies, policy_costs=policy_costs)


def run_ref_many(traces: Sequence[Trace], sys: SystemConfig,
                 mode: Union[str, Sequence[str]] = "ts",
                 blooms=None, serial: Optional[bool] = None,
                 policies=None, policy_costs=None) -> List[dict]:
    """The pre-optimization engine over the same grouped/batched path:
    O(bucket) work per slot, uniform ``2*bucket+4`` budget. Kept for
    bit-exactness property tests and the sim_speed steady-state A/B.
    Supports the runtime policy axis like :func:`run_many` (the
    reference engine mirrors the table-VM branch line for line)."""
    return _run_grouped(traces, sys, mode, blooms, ref=True, serial=serial,
                        policies=policies, policy_costs=policy_costs)


def run_policies(trace: Trace, sys: SystemConfig,
                 programs: Sequence[smcprog.PolicyProgram],
                 mode: str = "ts", bloom: Optional[tuple] = None,
                 derive_cost: bool = True,
                 serial: Optional[bool] = None) -> List[dict]:
    """Evaluate ONE trace under many candidate policies in vmapped
    policy-axis dispatches: the trace is repeated down the batch axis
    with one packed program per row, so a 256-program sweep compiles
    once per distinct table-length bucket (<= 3 for sanely-sized
    programs) instead of once per program — the scaling wall of the
    staged-constant path (ROADMAP item 5).

    ``derive_cost=True`` charges each program its length-derived SMC
    decision cost (``prog.smc_cycles()`` — matching
    ``sys.with_policy(prog)``); False keeps ``sys``'s existing cost
    (matching ``dataclasses.replace(sys, policy=prog)``). Returns one
    result dict per program, in input order, bit-identical to the
    equivalent staged-constant runs."""
    programs = list(programs)
    costs = ([p.smc_cycles() for p in programs] if derive_cost
             else [sys.smc_cycles_per_decision] * len(programs))
    return run_many([trace] * len(programs), sys, mode=mode, blooms=bloom,
                    serial=serial, policies=programs, policy_costs=costs)


def run(trace: Trace, sys: SystemConfig, mode: str = "ts",
        bloom: Optional[tuple] = None) -> dict:
    """mode: 'ts' | 'nots' | 'reference'. bloom: (words_u32, k, m_bits).

    'reference' is the Sec. 6 RTL reference system: a hardware memory
    controller at the modeled clock. Its math must coincide with 'ts' —
    that coincidence (validated in tests/benchmarks) IS the paper's
    time-scaling accuracy claim.

    A thin wrapper over a :func:`run_many` batch of one — single-trace
    and campaign paths share one compiled-program cache.
    """
    return run_many([trace], sys, mode=mode, blooms=bloom)[0]


def run_ref(trace: Trace, sys: SystemConfig, mode: str = "ts",
            bloom: Optional[tuple] = None) -> dict:
    """Single-trace wrapper over :func:`run_ref_many` (see there)."""
    return run_ref_many([trace], sys, mode=mode, blooms=bloom)[0]


# ---------------------------------------------------------------------------
# Streaming chunked-window driver: constant memory, length-independent
# compile keys, bit-identical to single-shot.
#
# The trace is consumed in windows of L = halo + chunk requests. Each
# window step (a) shifts the carried arrays left by ``chunk`` (retiring
# the ``chunk`` oldest entries, whose tags are provably final — see
# below) and appends the fresh chunk, (b) runs the SHARED slot body
# (:func:`_make_slot_body`) for a fixed per-window slot budget, with one
# twist: a slot is executed only while ``ptr <= L - _FRONTIER_UPTO``
# (the *freeze rule*), else it is an identity step. Freezing whole slots
# — rather than letting the frontier run off the window's edge — means
# the streamed slot sequence is exactly the single-shot slot sequence
# with identity steps inserted, so every carried value is bit-identical
# by induction; the inserted no-ops cost nothing but wall-clock.
#
# Finality of the retired prefix: after a window's scan, the freeze rule
# guarantees ptr > L - _FRONTIER_UPTO, in-order issue bounds unserved
# requests to indices >= ptr - window, and the halo satisfies
# halo >= _FRONTIER_UPTO + window — so every entry below ``chunk`` is
# issued AND served, and the window can emit its [0, chunk) slice as
# final output (window k covers global [k*chunk - halo, (k+1)*chunk -
# halo); the first ``halo`` emitted entries are the virtual warm-up
# prefix and are dropped by the accumulator). The window that exhausts
# the trace group ships with ``final=1``, lifting the freeze: its own
# scan drains every carried entry (the slot budget covers a full fresh
# chunk plus the halo, and chunk >= halo bounds the tail), and the
# consumer keeps its whole [0, L) emission instead of the [0, chunk)
# slice — no separate flush dispatch, same executable, same key.
#
# The carried halo holds the trailing ``halo = _FRONTIER_UPTO +
# max(window, dep_max)`` requests: the deepest lookback the frontier
# performs is max(window, dep) behind an issue point, and at a window
# handoff up to _FRONTIER_UPTO - 1 entries may sit unissued behind
# ``ptr``. The initial (virtual) halo is all-NOP with t_issue = 0 and
# t_resp = -1, so the frontier's lookback terms ``t_resp[j-k] + 1``
# evaluate to 0 — exactly the out-of-range defaults the single-shot
# engine uses for j - k < 0.
#
# Times stay ABSOLUTE int32 (only indices are rebased by -chunk at each
# shift), so a stream saturates at ~2^30 modeled cycles — a documented
# horizon, checked at the accumulator (RuntimeError on wrap), not a
# silent truncation.
# ---------------------------------------------------------------------------

DEFAULT_STREAM_CHUNK = 4096   # requests per window
DEFAULT_STREAM_DEP = 8        # max dep lookback admitted into a stream


@dataclasses.dataclass
class StreamState:
    """One stream's full inter-window carry: the :class:`EmulatorState`
    plus the window's trace arrays (the tail ``halo`` of which is the
    context the next window needs). A registered pytree, so the
    streaming runner donates and rebuilds it in place each window."""
    emu: EmulatorState
    kind: jnp.ndarray     # int32 [L]
    bank: jnp.ndarray     # int32 [L]
    row: jnp.ndarray      # int32 [L]
    delta: jnp.ndarray    # int32 [L]
    dep: jnp.ndarray      # int32 [L]


jax.tree_util.register_dataclass(
    StreamState,
    data_fields=["emu", "kind", "bank", "row", "delta", "dep"],
    meta_fields=[])


def stream_halo(sys: SystemConfig, dep_max: int = DEFAULT_STREAM_DEP) -> int:
    """Carried-context length: the issue frontier looks back at most
    ``max(window, dep)`` entries, plus up to ``_FRONTIER_UPTO - 1``
    unissued entries may trail the pointer at a window handoff (and the
    freeze slack is ``_FRONTIER_UPTO``)."""
    return _FRONTIER_UPTO + max(int(sys.window), int(dep_max))


def stream_slot_budget(chunk: int, sys: SystemConfig) -> int:
    """Per-window slot budget: at most ``chunk + _FRONTIER_UPTO - 1``
    requests become issuable in one window (the fresh chunk plus carried
    unissued entries), each costing at most 2 slots (idle hop + serve),
    plus queue-drain and freeze slack. The same budget covers the
    freeze-lifted final window — fresh chunk (2*chunk) plus carried
    queued entries (2*max(window, 2)) plus unissued stragglers and
    slack (12) — so the tail drains with no extra dispatch. Surplus
    slots freeze into identity steps, so any budget at or above the
    exact one is bit-identical (same argument as :func:`slot_budget`)."""
    return 2 * chunk + 2 * max(int(sys.window), 2) + 12


def stream_compile_key(chunk: int, batch: int, sys: SystemConfig, mode: str,
                       blooms=None,
                       dep_max: int = DEFAULT_STREAM_DEP,
                       policy_bucket: Optional[int] = None) -> tuple:
    """Cache key of one streaming window executable. Everything here is
    bounded by configuration — chunk, halo, slot budget, padded batch,
    system config, normalized mode, bloom shape, policy table-length
    bucket — and NOTHING depends on total trace length: a 1M-request
    stream and a 10k-request stream on the same config share one entry
    (the ``cache_stats`` regression in tests/test_streaming.py pins
    this). ``policy_bucket`` selects the runtime policy axis (callers
    pass a :func:`_policy_rt_sys`-normalized ``sys`` with it)."""
    return ("stream", int(chunk), stream_halo(sys, dep_max),
            stream_slot_budget(chunk, sys), _batch_bucket(batch), sys,
            _norm_mode(mode), _bloom_shape(blooms),
            None if policy_bucket is None else _policy_shape(policy_bucket))


def _stream_init(chunk: int, halo: int, sys: SystemConfig,
                 batch: Optional[int] = None) -> StreamState:
    """Window-0 carry: an all-virtual window (NOP trace, t_issue=0,
    t_resp=-1 — see the section comment) with ``ptr = L`` so the first
    shift lands the pointer exactly on the first real request. With
    ``batch``, every leaf gains a leading batch axis."""
    L = chunk + halo
    emu = EmulatorState.init(L, sys)
    emu = dataclasses.replace(emu, t_resp=jnp.full((L,), -1, jnp.int32),
                              ptr=jnp.int32(L))
    z = jnp.zeros((L,), jnp.int32)
    ss = StreamState(emu=emu, kind=jnp.full((L,), NOP, jnp.int32),
                     bank=z, row=z, delta=z, dep=z)
    if batch is None:
        return ss
    return jax.tree_util.tree_map(lambda a: jnp.stack([a] * batch), ss)


def _stream_step_core(ss: StreamState, ck, cb, cr, cd, cdep, final,
                      sys: SystemConfig, mode: str, bloom_words,
                      bloom_k: int, bloom_m: int, chunk: int, slots: int,
                      policy_table=None, policy_cost=None):
    """One window step (see the section comment for the correctness
    argument): shift by ``chunk``, scan the freeze-gated shared slot
    body for ``slots`` steps, and emit the whole [0, L) carry.
    ``final`` is a traced scalar (an operand, NOT a compile-key
    constant): the last real window sets it to lift the freeze so its
    own scan drains the entire tail in-budget — no separate flush
    dispatch (requires chunk >= halo, enforced by the driver, so the
    final window's emission covers every still-carried entry)."""
    C = chunk
    L = ss.kind.shape[0]
    kind = jnp.concatenate([ss.kind[C:], ck])
    bank = jnp.concatenate([ss.bank[C:], cb])
    row = jnp.concatenate([ss.row[C:], cr])
    delta = jnp.concatenate([ss.delta[C:], cd])
    dep = jnp.concatenate([ss.dep[C:], cdep])
    e = ss.emu
    emu = dataclasses.replace(
        e,
        t_issue=jnp.concatenate([e.t_issue[C:], jnp.zeros((C,), jnp.int32)]),
        t_resp=jnp.concatenate([e.t_resp[C:], jnp.full((C,), BIG, jnp.int32)]),
        # queue entries and the pointer are window-local indices: rebase
        # (carried live entries are >= C — they sit in the halo)
        queue=jnp.where(e.queue >= 0, e.queue - C, e.queue),
        ptr=e.ptr - C)

    # freeze rule: a slot only executes while the frontier cannot run off
    # the loaded window (or during the lifted flush). Threaded through the
    # body's predicates — NOT a lax.cond, which vmap would lower to both
    # branches + an O(L) select over the carry per slot (see
    # _make_slot_body); frozen slots cost the same O(Q)+O(1) as live ones.
    live_cut = jnp.int32(L - _FRONTIER_UPTO)
    lifted = final != 0
    step = _make_slot_body(kind, bank, row, delta, dep, sys, mode,
                           bloom_words, bloom_k, bloom_m,
                           gate=lambda st: lifted | (st.ptr <= live_cut),
                           policy_table=policy_table,
                           policy_cost=policy_cost)
    emu, _ = jax.lax.scan(lambda st, _: (step(st), None), emu, None,
                          length=slots)
    # emit the full [0, L) carry every window: the consumer slices
    # [0, chunk) for interior windows and keeps everything for the
    # final (freeze-lifted) one — constant shapes, ONE executable
    out = (kind, emu.t_issue, emu.t_resp, emu.ptr)
    return StreamState(emu=emu, kind=kind, bank=bank, row=row,
                       delta=delta, dep=dep), out


def _build_stream_runner(key: tuple) -> "_CachedRunner":
    """Construct the (lazily-compiled) window-step runner for one
    streaming cache key: :func:`_stream_step_core` vmapped over the
    padded batch axis, jitted with the carried :class:`StreamState` and
    the freshly-staged chunk arrays donated (constant device memory —
    each window rebuilds the carry in place). Post-``is_final``
    argument order matches :func:`_build_runner`: Bloom words (when
    keyed), then stacked policy tables + cost pairs (when keyed)."""
    _, C, H, SL, bb, sys, mode, bshape, pshape = key
    has_bloom = bshape is not None
    has_pol = pshape is not None
    if has_bloom:
        stacked, _, bk, bm = bshape
        words_axis = 0 if stacked == "stacked" else None
    axes = (0,) * 6 + ((words_axis,) if has_bloom else ()) \
        + ((0, 0) if has_pol else ())

    def fn(ss, ck, cb, cr, cd, cdep, is_final, *rest):
        def one(s, a, b, c, d, e, *r):
            i = 0
            bloom_args = (None, 0, 1)
            if has_bloom:
                bloom_args = (r[0], bk, bm)
                i = 1
            pol = ({"policy_table": r[i], "policy_cost": r[i + 1]}
                   if has_pol else {})
            return _stream_step_core(s, a, b, c, d, e, is_final,
                                     sys, mode, *bloom_args, C, SL, **pol)
        return jax.vmap(one, in_axes=axes)(ss, ck, cb, cr, cd, cdep, *rest)

    jitted = jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4, 5))
    avals = [lambda: _stream_init(C, H, sys, batch=bb)] + \
        [((bb, C), jnp.int32)] * 5 + [((), jnp.int32)]
    if bshape is not None:
        wshape = (bshape[1],) if bshape[0] == "shared" else (bb, bshape[1])
        avals = avals + [(wshape, jnp.uint32)]
    if has_pol:
        avals = avals + [((bb, pshape[1] + 1, 4), jnp.int32),
                         ((bb, 2), jnp.int32)]
    return _CachedRunner(jitted, avals)


def _stream_fn(key: tuple) -> "_CachedRunner":
    """Get-or-build the streaming runner for ``key`` in the SAME
    module-level LRU as the batched executables (same lock, same
    hit/miss/eviction counters — the ``"stream"`` tag namespaces the
    keys)."""
    with _CACHE_LOCK:
        fn = _COMPILE_CACHE.get(key)
        if fn is not None:
            _CACHE_STATS["hits"] += 1
            _COMPILE_CACHE.move_to_end(key)
            return fn
        _CACHE_STATS["misses"] += 1
        runner = _build_stream_runner(key)
        _COMPILE_CACHE[key] = runner
        while len(_COMPILE_CACHE) > _CACHE_CAP:
            _COMPILE_CACHE.popitem(last=False)
            _CACHE_STATS["evictions"] += 1
    return runner


def _nop_fields(k: int) -> tuple:
    z = np.zeros(k, np.int32)
    return (np.full(k, NOP, np.int32), z, z, z, z)


class _Chunker:
    """Re-buffer an arbitrary stream of :class:`Trace` windows into
    exact ``chunk``-sized int32 field blocks, NOP-padding past the end.
    Accepts a single Trace, an iterable of Traces, or a zero-arg
    callable returning one (a generator factory). Holds O(chunk +
    largest yielded window) host memory — never the whole stream."""

    __slots__ = ("it", "chunk", "dep_max", "parts", "buffered",
                 "exhausted", "n")

    def __init__(self, stream, chunk: int, dep_max: int):
        if isinstance(stream, Trace):
            stream = (stream,)
        elif callable(stream):
            stream = stream()
        self.it = iter(stream)
        self.chunk = chunk
        self.dep_max = dep_max
        self.parts: list = []    # pending (kind, bank, row, delta, dep)
        self.buffered = 0
        self.exhausted = False
        self.n = 0               # total requests pulled (incl. user NOPs)

    @property
    def done(self) -> bool:
        return self.exhausted and self.buffered == 0

    def _pull(self) -> None:
        try:
            tr = next(self.it)
        except StopIteration:
            self.exhausted = True
            return
        if not isinstance(tr, Trace):
            raise TypeError(
                f"streams must yield Trace windows, got {type(tr).__name__}")
        dep = np.asarray(tr.dep, np.int32)
        if dep.size and (int(dep.max()) > self.dep_max or int(dep.min()) < 0):
            raise ValueError(
                f"stream window has dep={int(dep.max())} outside "
                f"[0, dep_max={self.dep_max}]; raise dep_max (grows the "
                f"carried halo) or re-author the trace")
        self.parts.append(tuple(
            np.asarray(getattr(tr, f), np.int32)
            for f in ("kind", "bank", "row", "delta", "dep")))
        self.buffered += tr.n
        self.n += tr.n

    def next_block(self) -> tuple:
        """The next ``chunk`` requests as (kind, bank, row, delta, dep)
        arrays; all-NOP once the stream is exhausted."""
        while self.buffered < self.chunk and not self.exhausted:
            self._pull()
        fields: list = [[] for _ in range(5)]
        need = self.chunk
        while need and self.parts:
            part = self.parts[0]
            take = min(need, part[0].shape[0])
            for f, arr in zip(fields, part):
                f.append(arr[:take])
            if take == part[0].shape[0]:
                self.parts.pop(0)
            else:
                self.parts[0] = tuple(arr[take:] for arr in part)
            self.buffered -= take
            need -= take
        if need:
            for f, p in zip(fields, _nop_fields(need)):
                f.append(p)
        return tuple(np.concatenate(f) if len(f) != 1 else f[0]
                     for f in fields)


class _StreamAccum:
    """Per-stream output accumulator over emitted window blocks.

    ``collect='aggregate'`` keeps O(1) state: int64-exact latency sums
    plus running maxima (for int32-range values np.mean's float64
    pairwise sum is exact too, so the reported mean is identical to the
    full-mode one). ``collect='full'`` additionally retains every
    emitted block and reassembles exact per-request ``t_issue`` /
    ``t_resp`` arrays — drop-in comparable with single-shot
    :func:`run`, at O(stream length) host memory."""

    __slots__ = ("collect", "halo", "blocks", "n_requests", "lat_sum",
                 "last_resp", "last_issue")

    def __init__(self, collect: str, halo: int):
        self.collect = collect
        self.halo = halo
        self.blocks: list = []
        self.n_requests = 0
        self.lat_sum = 0
        self.last_resp = 0
        self.last_issue = 0

    def feed(self, kind_blk, issue_blk, resp_blk) -> None:
        valid = kind_blk != NOP  # virtual-halo and padding entries are NOP
        if valid.any():
            resp = resp_blk[valid].astype(np.int64)
            issue = issue_blk[valid].astype(np.int64)
            if (resp >= int(BIG)).any() or (resp < 0).any():
                raise RuntimeError(
                    "streaming invariant violated: a retired window slice "
                    "holds an unserved or time-wrapped request (t_resp "
                    "outside [0, 2^30)) — slot budget or int32 time "
                    "horizon exceeded")
            self.n_requests += int(valid.sum())
            self.lat_sum += int((resp - issue).sum())
            self.last_resp = max(self.last_resp, int(resp.max()))
            self.last_issue = max(self.last_issue, int(issue.max()))
        if self.collect == "full":
            self.blocks.append((np.asarray(kind_blk),
                                np.asarray(issue_blk),
                                np.asarray(resp_blk)))

    def result(self, n: int, hits: int, served: int, dram_ticks: int,
               smc: int, sys: SystemConfig, mode: str) -> dict:
        if served != self.n_requests:
            raise RuntimeError(
                f"streaming invariant violated: {served} serve slots vs "
                f"{self.n_requests} retired non-NOP requests")
        exec_cycles = max(self.last_resp, self.last_issue)
        out = {
            "exec_cycles": np.int32(exec_cycles),
            "row_hits": np.int32(hits),
            "served": np.int32(served),
            "dram_ticks": np.int32(dram_ticks),
            "smc_fpga_cycles": np.int32(smc),
            "exec_seconds": sys.cycles_to_seconds(exec_cycles, mode),
            "mode": mode,
            "n_requests": self.n_requests,
        }
        if self.collect == "full":
            H = self.halo
            kind = np.concatenate([b[0] for b in self.blocks])[H:H + n]
            t_issue = np.concatenate([b[1] for b in self.blocks])[H:H + n]
            t_resp = np.concatenate([b[2] for b in self.blocks])[H:H + n]
            lat = t_resp - t_issue
            ok = (kind != NOP) & (t_resp < int(BIG))
            out["avg_load_latency_cycles"] = \
                float(lat[ok].mean()) if ok.any() else 0.0
            out["t_resp"] = t_resp
            out["t_issue"] = t_issue
        else:
            out["avg_load_latency_cycles"] = \
                self.lat_sum / self.n_requests if self.n_requests else 0.0
        return out


def prepare_stream_tasks(streams: Sequence, sys: SystemConfig,
                         mode: Union[str, Sequence[str]], blooms,
                         results: List[Optional[dict]],
                         chunk: int = DEFAULT_STREAM_CHUNK,
                         dep_max: int = DEFAULT_STREAM_DEP,
                         collect: str = "full",
                         policies=None, policy_costs=None,
                         ) -> List["executor.StreamTask"]:
    """Plan a :func:`run_stream_many` call into executable
    :class:`repro.core.executor.StreamTask`s WITHOUT running them —
    the streaming analogue of :func:`prepare_tasks`: grouping (by
    normalized mode only — there is no length bucket, that is the
    point — plus the policy table bucket when the runtime policy axis
    rides along), runner resolution and priming on the caller's thread,
    and closures that feed windows / consume emitted blocks / finalize
    per-stream records into disjoint ``results`` slots. ``policies`` /
    ``policy_costs`` are per-STREAM (one program per stream row, see
    :func:`_normalize_policies`); the packed tables are per-group
    constants appended to every window's arguments."""
    streams = list(streams)
    n = len(streams)
    modes = _check_modes([mode] * n if isinstance(mode, str) else mode, n)
    blooms = _normalize_blooms(blooms, n)
    pol = _normalize_policies(policies, policy_costs, sys, n)
    H = stream_halo(sys, dep_max)
    if not isinstance(chunk, (int, np.integer)) or isinstance(chunk, bool) \
            or chunk < H:
        raise ValueError(
            f"stream chunk must be an int >= halo ({H} = {_FRONTIER_UPTO} "
            f"+ max(window={sys.window}, dep_max={dep_max})) so the final "
            f"window drains the whole tail in-budget, got {chunk!r}")
    if collect not in ("full", "aggregate"):
        raise ValueError(
            f"collect must be 'full' or 'aggregate', got {collect!r}")
    chunk = int(chunk)
    SL = stream_slot_budget(chunk, sys)
    L = chunk + H

    groups: dict = {}
    for i in range(n):
        lb = None if pol is None else smcprog.table_bucket(pol[0][i].n_ops)
        groups.setdefault((_norm_mode(modes[i]), lb), []).append(i)

    tasks: List[executor.StreamTask] = []
    for (gmode, lb), idxs in groups.items():
        gsys = sys if lb is None else _policy_rt_sys(sys)
        key = stream_compile_key(chunk, len(idxs), gsys, gmode, blooms,
                                 dep_max, lb)
        fn = _stream_fn(key).prime()
        bb = _batch_bucket(len(idxs))
        if blooms is None:
            wargs = ()
        elif isinstance(blooms, tuple):
            wargs = (jnp.asarray(blooms[0]),)
        else:
            words = np.stack([np.asarray(blooms[i][0]) for i in idxs])
            if bb > len(idxs):
                words = np.concatenate(
                    [words, np.repeat(words[:1], bb - len(idxs), axis=0)])
            wargs = (jnp.asarray(words),)
        if lb is not None:  # per-group policy operands, shared by windows
            tables = np.stack(
                [smcprog.pack_program(pol[0][i], lb) for i in idxs])
            cost = np.asarray(
                [_policy_cost_pair(sys, pol[1][i]) for i in idxs], np.int32)
            if bb > len(idxs):
                tables = np.concatenate(
                    [tables, np.repeat(tables[:1], bb - len(idxs), axis=0)])
                cost = np.concatenate(
                    [cost, np.repeat(cost[:1], bb - len(idxs), axis=0)])
            wargs = wargs + (jnp.asarray(tables), jnp.asarray(cost))

        def pack(idxs=idxs, bb=bb):
            ctx = {
                "chunkers": [_Chunker(streams[i], chunk, dep_max)
                             for i in idxs],
                "accs": [_StreamAccum(collect, H) for _ in idxs],
                # index of the freeze-lifted final window; written by
                # windows() BEFORE that window's args are queued, so the
                # (possibly prefetching) consumer always sees it in time
                "final_idx": None,
                "fed": 0,
            }
            return _stream_init(chunk, H, sys, batch=bb), ctx

        def windows(ctx, bb=bb, wargs=wargs):
            # the window whose assembly exhausts every chunker is the
            # final one: it ships with the freeze LIFTED (final=1) and
            # drains the whole tail in-budget — no separate flush
            # dispatch (SL covers a full fresh chunk plus the carried
            # halo, the exact worst case)
            chunkers = ctx["chunkers"]
            filler = _nop_fields(chunk)
            k = 0
            while not all(c.done for c in chunkers):
                blocks = [c.next_block() for c in chunkers]
                blocks += [filler] * (bb - len(blocks))
                final = all(c.done for c in chunkers)
                if final:
                    ctx["final_idx"] = k
                yield tuple(
                    jnp.asarray(np.stack([b[i] for b in blocks]))
                    for i in range(5)) + (jnp.int32(final),) + wargs
                k += 1
            if k == 0:  # every stream empty: one all-NOP final window
                ctx["final_idx"] = 0
                blocks = [filler] * bb
                yield tuple(
                    jnp.asarray(np.stack([b[i] for b in blocks]))
                    for i in range(5)) + (jnp.int32(1),) + wargs

        def consume(out, ctx, idxs=idxs):
            kind_blk, issue_blk, resp_blk, ptr = out
            final = ctx["final_idx"] == ctx["fed"]
            ctx["fed"] += 1
            # interior windows retire exactly [0, chunk); the final one
            # keeps its whole [0, L) carry (tail included — that is the
            # flush)
            keep = L if final else chunk
            for j, acc in enumerate(ctx["accs"]):
                acc.feed(kind_blk[j, :keep], issue_blk[j, :keep],
                         resp_blk[j, :keep])
            if not final:
                lag = ptr[:len(idxs)] <= (L - _FRONTIER_UPTO)
                if lag.any():
                    raise RuntimeError(
                        f"streaming invariant violated: issue frontier "
                        f"fell behind the window "
                        f"(ptr={ptr[:len(idxs)].tolist()}, window={L}, "
                        f"slots={SL}) — slot budget too small")

        def finalize(final_state, ctx, idxs=idxs):
            e = final_state.emu
            hits = np.asarray(e.hits)
            served = np.asarray(e.served_n)
            dram_now = np.asarray(e.dram_now)
            smc = np.asarray(e.smc_fpga_cycles)
            # the fault carry rides EmulatorState through every window
            # untouched by the shift, so the final window's state IS the
            # whole stream's flip record (bit-identical to single-shot)
            fhost = (None if sys.faults is None else
                     jax.tree_util.tree_map(np.asarray, e.faults))
            for j, i in enumerate(idxs):
                results[i] = ctx["accs"][j].result(
                    ctx["chunkers"][j].n, int(hits[j]), int(served[j]),
                    int(dram_now[j]), int(smc[j]), sys, modes[i])
                if fhost is not None:
                    frow = {kk: v[j] for kk, v in fhost.items()}
                    results[i].update(faultmod.fault_result_fields(frow))
                    results[i]["bit_error_rate"] = \
                        int(frow["vptr"]) / max(int(served[j]), 1)

        ptag = "" if lb is None else f":pol{lb}"
        tasks.append(executor.StreamTask(
            fn=fn, pack=pack, windows=windows, consume=consume,
            finalize=finalize,
            label=f"stream:c{chunk}x{len(idxs)}:{gmode}{ptag}",
            cost=SL * bb))
    return tasks


def run_stream_many(streams: Sequence, sys: SystemConfig,
                    mode: Union[str, Sequence[str]] = "ts", blooms=None,
                    chunk: int = DEFAULT_STREAM_CHUNK,
                    dep_max: int = DEFAULT_STREAM_DEP,
                    collect: str = "full",
                    serial: Optional[bool] = None,
                    policies=None, policy_costs=None) -> List[dict]:
    """Evaluate many UNBOUNDED traces under one ``SystemConfig`` in
    lockstep constant-memory windows.

    Each stream is a :class:`Trace`, an iterable of Trace windows, or a
    zero-arg callable returning one (a generator factory) — total
    length need not be known, and with an iterator input it is never
    materialized. Streams sharing a normalized mode batch into ONE
    window executable whose compile key (:func:`stream_compile_key`)
    is independent of trace length; exhausted streams idle on NOP
    windows until the whole group drains, and the window that exhausts
    the group ships with the freeze lifted so its own scan retires
    every tail — no extra flush dispatch. Device memory is
    O(batch * (chunk + halo));
    host memory is O(chunk) per stream with ``collect='aggregate'``
    (exact int64 aggregates only) or O(length) with the default
    ``collect='full'`` (adds exact per-request ``t_resp`` /
    ``t_issue``).

    Results are bit-identical to single-shot :func:`run` /
    :func:`run_many` on any trace both paths support, for every chunk
    size >= the halo — pinned by tests/test_streaming.py and the
    hypothesis property in tests/test_property.py. ``dep_max`` bounds
    admissible ``dep`` lookbacks (it sizes the carried halo); times
    saturate at the int32 horizon (~2^30 modeled cycles), checked at
    the accumulator."""
    streams = list(streams)
    results: List[Optional[dict]] = [None] * len(streams)
    tasks = prepare_stream_tasks(streams, sys, mode, blooms, results,
                                 chunk=chunk, dep_max=dep_max,
                                 collect=collect, policies=policies,
                                 policy_costs=policy_costs)
    _execute_entry_point(tasks, serial)
    return results


def run_stream(stream, sys: SystemConfig, mode: str = "ts",
               bloom: Optional[tuple] = None,
               chunk: int = DEFAULT_STREAM_CHUNK,
               dep_max: int = DEFAULT_STREAM_DEP,
               collect: str = "full") -> dict:
    """Single-stream wrapper over :func:`run_stream_many` (see there)."""
    return run_stream_many([stream], sys, mode=mode, blooms=bloom,
                           chunk=chunk, dep_max=dep_max,
                           collect=collect)[0]
