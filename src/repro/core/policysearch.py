"""Policy autotuning: random + evolutionary search over the smcprog op
space, evaluated at search scale on the runtime policy axis.

The paper's promise is a *software-defined* memory controller: policies
are programs, so better policies can be FOUND, not just written. This
module closes that loop. A population of random
:class:`~repro.core.smcprog.PolicyProgram` candidates (seeded with the
built-in schedulers so the search never regresses below the best known
baseline) evolves by mutation + crossover, and every generation is
scored with ONE vmapped dispatch through
:func:`repro.core.emulator.run_policies` — the runtime policy operand
means a whole generation shares a single executable, and because every
candidate is capped at ``max_ops`` <= one table bucket, the entire
search compiles exactly once per (trace bucket, mode).

Usage::

    from repro.core.policysearch import search

    res = search(trace, JETSON_NANO, generations=8, population=32, seed=0)
    print(res.summary())        # tuned-vs-baseline table
    best = res.best             # a PolicyProgram; run it anywhere

Determinism: the search is a pure function of (trace, sys, mode, seed,
knobs) — candidate generation uses a seeded ``numpy.random.RandomState``
and fitness comes from the bit-deterministic emulator, so a re-run
reproduces the same winner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import emulator, smcprog
from repro.core.smcprog import (OP_CONST, OP_SELECT, PolicyProgram,
                                _BINARY, _UNARY, builtin_programs,
                                table_bucket)

__all__ = ["SearchResult", "random_program", "mutate", "crossover",
           "search"]

# candidate instruction pools: every environment load plus the full ALU.
# hammer_ct / para_rand are deterministic env loads too (seeded in the
# engine), so they stay in the pool — a schedule may legitimately use
# randomized tie-breaking.
_LOADS: Tuple[int, ...] = tuple(
    range(smcprog.OP_AGE, smcprog.OP_PARA_RAND + 1))
_ALU: Tuple[int, ...] = tuple(sorted(_BINARY)) + (smcprog.OP_NOT,
                                                  OP_SELECT)
_IMM_LO, _IMM_HI = -8, 65             # const range: small masks/weights


def _random_row(rng: np.random.RandomState, i: int,
                p_load: float = 0.45) -> Tuple[int, int, int, int]:
    """One valid SSA row for table position ``i`` (operands < i)."""
    if i == 0 or rng.random_sample() < p_load:
        if rng.random_sample() < 0.25:
            return (OP_CONST, 0, 0, int(rng.randint(_IMM_LO, _IMM_HI)))
        return (int(_LOADS[rng.randint(len(_LOADS))]), 0, 0, 0)
    op = int(_ALU[rng.randint(len(_ALU))])
    a = int(rng.randint(i))
    b = int(rng.randint(i))
    if op in _UNARY:
        return (op, a, 0, 0)
    if op == OP_SELECT:
        return (op, a, b, int(rng.randint(i)))   # imm is the 3rd operand
    return (op, a, b, 0)


def random_program(rng: np.random.RandomState, max_ops: int = 8,
                   name: str = "rand") -> PolicyProgram:
    """A random valid program of 2..``max_ops`` rows; the last value is
    the score (so every instruction is at least reachable from it)."""
    n = int(rng.randint(2, max_ops + 1))
    rows = tuple(_random_row(rng, i) for i in range(n))
    return PolicyProgram(rows, score_reg=n - 1, name=name).validate()


def mutate(prog: PolicyProgram, rng: np.random.RandomState,
           max_ops: int = 8, name: str = "mut") -> PolicyProgram:
    """One random edit: replace a row, re-pick an operand, retarget the
    score register, perturb a constant, or (under the cap) grow by one
    combining row. Always returns a valid program in the same table
    bucket (``n_ops`` <= ``max_ops``)."""
    rows = [tuple(r) for r in prog.table]
    score = prog.score_reg
    n = len(rows)
    kind = rng.randint(5)
    if kind == 0:                                 # replace one row
        i = int(rng.randint(n))
        rows[i] = _random_row(rng, i)
    elif kind == 1 and n > 1:                     # re-pick an operand
        i = int(rng.randint(1, n))
        op, a, b, imm = rows[i]
        if op in _BINARY or op == OP_SELECT:
            if rng.random_sample() < 0.5:
                a = int(rng.randint(i))
            else:
                b = int(rng.randint(i))
            rows[i] = (op, a, b, imm)
        elif op in _UNARY:
            rows[i] = (op, int(rng.randint(i)), 0, 0)
    elif kind == 2:                               # retarget the score
        score = int(rng.randint(n))
    elif kind == 3:                               # perturb a const
        consts = [i for i, r in enumerate(rows) if r[0] == OP_CONST]
        if consts:
            i = consts[int(rng.randint(len(consts)))]
            op, a, b, imm = rows[i]
            rows[i] = (op, a, b,
                       int(np.clip(imm + rng.randint(-4, 5),
                                   _IMM_LO, _IMM_HI)))
        else:
            i = int(rng.randint(n))
            rows[i] = _random_row(rng, i)
    else:                                         # grow by one row
        if n < max_ops:
            rows.append(_random_row(rng, n, p_load=0.0)
                        if n > 0 else _random_row(rng, 0))
            score = n                             # new row is the score
        else:
            i = int(rng.randint(n))
            rows[i] = _random_row(rng, i)
    return PolicyProgram(tuple(rows), score_reg=score,
                         name=name).validate()


def crossover(a: PolicyProgram, b: PolicyProgram,
              rng: np.random.RandomState,
              name: str = "xover") -> PolicyProgram:
    """Positional splice: the child takes ``a``'s prefix and ``b``'s
    suffix at one cut point. Rows keep their table positions, so SSA
    operand validity (refs < own index) is preserved by construction;
    the child inherits ``b``'s length and score register."""
    cut = int(rng.randint(0, min(a.n_ops, b.n_ops) + 1))
    rows = tuple(a.table[:cut]) + tuple(b.table[cut:])
    return PolicyProgram(rows, score_reg=b.score_reg,
                         name=name).validate()


@dataclasses.dataclass
class SearchResult:
    """Outcome of one :func:`search` run."""
    best: PolicyProgram                  # highest-fitness program found
    best_fitness: float                  # its objective value (lower=better)
    baseline: PolicyProgram              # the named baseline program
    baseline_fitness: float
    objective: str                       # record field minimized
    history: List[dict]                  # per-generation {gen, best, mean}
    n_evaluated: int                     # distinct programs scored
    n_dispatches: int                    # device dispatches spent
    leaderboard: List[dict]              # top programs vs baseline

    @property
    def improvement(self) -> float:
        """baseline/best objective ratio (>1 means the search won)."""
        return self.baseline_fitness / max(self.best_fitness, 1e-12)

    def summary(self) -> str:
        """Tuned-vs-baseline table, one line per leaderboard entry."""
        lines = [f"objective: {self.objective} (lower is better); "
                 f"baseline {self.baseline.name} = "
                 f"{self.baseline_fitness:.3f}; "
                 f"{self.n_evaluated} programs in "
                 f"{self.n_dispatches} dispatches"]
        for row in self.leaderboard:
            lines.append(
                f"  {row['name']:<16} {row[self.objective]:>10.3f}  "
                f"x{row['vs_baseline']:.4f} vs baseline  "
                f"({row['n_ops']} ops, digest {row['digest']})")
        return "\n".join(lines)


def _seed_population(rng: np.random.RandomState, population: int,
                     max_ops: int, seeds: Sequence[PolicyProgram],
                     baseline: PolicyProgram) -> List[PolicyProgram]:
    pop: List[PolicyProgram] = [baseline]
    pop += [p for p in seeds if p.digest != baseline.digest]
    k = 0
    while len(pop) < population:
        pop.append(random_program(rng, max_ops, name=f"rand{k}"))
        k += 1
    return pop[:population]


def search(trace, sys, mode: str = "ts", *,
           generations: int = 6, population: int = 24,
           max_ops: int = 8, elite: int = 4, seed: int = 0,
           baseline: str = "frfcfs",
           objective: str = "avg_load_latency_cycles",
           seeds: Optional[Sequence[PolicyProgram]] = None,
           derive_cost: bool = False,
           serial: Optional[bool] = None) -> SearchResult:
    """Evolve scheduling policies for one workload.

    Every generation scores its not-yet-seen candidates with ONE
    :func:`emulator.run_policies` dispatch (fitness of repeat
    candidates is memoized by content digest). ``max_ops`` <=
    :data:`smcprog.TABLE_BUCKET_FLOOR` keeps the whole search inside
    one table bucket — one XLA compile for all generations.

    ``seeds`` (default: all built-in schedulers) join generation 0, so
    the result can only improve on the best known hand-written policy;
    ``baseline`` names the program the leaderboard compares against.
    ``derive_cost=False`` (default) scores pure scheduling quality —
    every candidate pays ``sys``'s decision cost; ``True`` charges each
    program its length-derived cost instead.
    """
    if elite < 1 or population < 2:
        raise ValueError(f"need population >= 2 and elite >= 1, got "
                         f"population={population}, elite={elite}")
    if max_ops < 2:
        raise ValueError(f"max_ops must be >= 2, got {max_ops}")
    builtins = builtin_programs()
    if seeds is None:
        seeds = [p for p in builtins.values()
                 if table_bucket(p.n_ops) <= table_bucket(max_ops)]
    if baseline in builtins:
        base_prog = builtins[baseline]
    else:
        by_name = {p.name: p for p in seeds}
        if baseline not in by_name:
            raise ValueError(f"baseline {baseline!r} is neither a "
                             f"built-in nor among seeds "
                             f"{sorted(by_name)}")
        base_prog = by_name[baseline]

    rng = np.random.RandomState(seed)
    pop = _seed_population(rng, population, max_ops, seeds, base_prog)
    scores: Dict[str, float] = {}        # digest -> objective value
    by_digest: Dict[str, PolicyProgram] = {}
    history: List[dict] = []
    n_dispatches = 0

    def fitness(p: PolicyProgram) -> float:
        return scores[p.digest]

    for gen in range(generations):
        todo, seen = [], set()
        for p in pop:
            if p.digest not in scores and p.digest not in seen:
                todo.append(p)
                seen.add(p.digest)
        if todo:
            recs = emulator.run_policies(trace, sys, todo, mode=mode,
                                         derive_cost=derive_cost,
                                         serial=serial)
            n_dispatches += 1
            for p, r in zip(todo, recs):
                scores[p.digest] = float(r[objective])
                by_digest[p.digest] = p
        pop.sort(key=lambda p: (fitness(p), p.n_ops))
        history.append({
            "gen": gen,
            "best": fitness(pop[0]),
            "mean": float(np.mean([fitness(p) for p in pop])),
            "evaluated": len(scores),
        })
        if gen == generations - 1:
            break
        elites = pop[:elite]
        nxt = list(elites)
        k = 0
        while len(nxt) < population:
            r = rng.random_sample()
            tag = f"g{gen + 1}c{k}"
            if r < 0.55:
                parent = elites[int(rng.randint(len(elites)))]
                nxt.append(mutate(parent, rng, max_ops,
                                  name=f"mut-{tag}"))
            elif r < 0.8 and len(elites) >= 2:
                i, j = rng.choice(len(elites), size=2, replace=False)
                nxt.append(crossover(elites[int(i)], elites[int(j)],
                                     rng, name=f"xo-{tag}"))
            else:
                nxt.append(random_program(rng, max_ops,
                                          name=f"rand-{tag}"))
            k += 1
        pop = nxt

    base_fit = scores[base_prog.digest]
    ranked = sorted(by_digest.values(), key=lambda p: (fitness(p), p.n_ops))
    leaderboard = [{
        "name": p.name, "digest": p.digest, "n_ops": p.n_ops,
        objective: fitness(p),
        "vs_baseline": base_fit / max(fitness(p), 1e-12),
    } for p in ranked[:max(elite, 5)]]
    best = ranked[0]
    return SearchResult(
        best=best, best_fitness=fitness(best),
        baseline=base_prog, baseline_fitness=base_fit,
        objective=objective, history=history,
        n_evaluated=len(scores), n_dispatches=n_dispatches,
        leaderboard=leaderboard)
