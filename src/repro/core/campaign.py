"""Batched emulation campaigns over (workload x system x mode x technique).

The paper's methodology (Secs. 6-8; PiDRAM / DRAM Bender share it) is
sweep-heavy: one DRAM technique is judged across many workloads, sizes,
system configs, and evaluation modes. Point-at-a-time evaluation pays a
fresh ``jax.jit`` compile of the ``2N+4``-step scan for every sweep
point; a :class:`Campaign` instead collects the whole grid, groups
points by compile key (trace-length bucket, ``SystemConfig``, mode,
Bloom-filter shape), executes each group as ONE vmapped
:func:`repro.core.emulator.run_many` call, and returns tidy per-point
records in submission order.

Usage::

    from repro.core.campaign import Campaign

    c = Campaign()
    for kern, tr in traces_by_kernel.items():
        c.add(tr, JETSON_NANO, mode="ts", workload=kern)
        c.add(tr, JETSON_NANO, mode="ts", bloom=bloom_tuple,
              workload=kern, technique="trcd")
    records = c.run()          # [{workload, technique, exec_cycles, ...}]

Results are bit-identical to looping ``emulator.run`` over the points —
the batch axis only vectorizes the same exact int32 arithmetic — but a
sweep compiles at most once per group and dispatches once per group.
Since PR 5 the groups themselves no longer execute serially either:
``run()`` prepares every group and hands the batch to
``repro.core.executor``, which overlaps host-side packing with device
compute and runs independent groups concurrently (``run(serial=True)``
keeps the old in-order loop for A/B). With more than one local device,
each group's batch axis additionally shards via ``shard_map``
(``emulator.set_sharding``).

Unbounded workloads are one more grid axis: ``add(stream, sys,
stream=True, chunk=...)`` accepts an iterable (or generator factory) of
``Trace`` windows and routes through ``emulator.run_stream_many`` — the
constant-memory chunked-window driver — so technique x workload sweeps
can replay production-scale traces next to padded micro-traces in one
campaign. Stream points group on ``(chunk, sys, mode, bloom-shape)``
with no length bucket at all.

Policy sweeps (PR 4) are one more grid axis: :meth:`Campaign.add_policy_grid`
fans a trace out across a set of :class:`repro.core.smcprog.PolicyProgram`
schedulers. Programs hash by instruction-table content, so each distinct
program forms its own compile-key group (one batched dispatch per
program), while same-content programs — and repeated traces under one
program — share a group.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core import emulator, executor
from repro.core.emulator import Trace
from repro.core.smcprog import PolicyProgram
from repro.core.timescale import SystemConfig


@dataclasses.dataclass
class Point:
    """One grid point. ``meta`` is carried through to the result.

    ``stream=True`` marks an unbounded point: ``trace`` is then a
    Trace, an iterable of Trace windows, or a zero-arg callable
    returning one, evaluated through the constant-memory
    ``emulator.run_stream_many`` path in windows of ``chunk``
    requests."""
    trace: Any
    sys: SystemConfig
    mode: str = "ts"
    bloom: Optional[tuple] = None       # (words_u32, k, m_bits)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    stream: bool = False
    chunk: Optional[int] = None         # stream window size (stream only)

    def group_key(self) -> tuple:
        # emulator.group_key is the single source of truth for bucket /
        # mode / bloom-shape normalization; slot budget and batch axis
        # are derived per group inside the run_many call
        if self.stream:
            # no length bucket by construction: streamed points group on
            # (chunk, sys, mode, bloom-shape) alone, whatever their size
            chunk = self.chunk or emulator.DEFAULT_STREAM_CHUNK
            return ("stream", chunk, self.sys,
                    emulator._norm_mode(self.mode),
                    emulator._bloom_shape(self.bloom))
        return emulator.group_key(self.trace.n, self.sys, self.mode,
                                  self.bloom)


class Campaign:
    """Collect grid points, execute them in compile-key groups.

    ``add`` order is preserved in ``run()``'s output; extra keyword
    arguments to ``add`` (workload name, technique label, size, ...)
    come back verbatim on each record, which is what makes the output
    tidy-data-friendly for the paper-figure benchmarks.
    """

    def __init__(self) -> None:
        self.points: List[Point] = []

    def add(self, trace, sys: SystemConfig, mode: str = "ts",
            bloom: Optional[tuple] = None, stream: bool = False,
            chunk: Optional[int] = None, **meta) -> "Campaign":
        # a real exception, not an assert: grid-driving scripts run
        # under `python -O` too, where asserts vanish silently
        emulator.check_mode(mode)
        if not stream and not isinstance(trace, Trace):
            raise ValueError(
                f"non-stream points need a Trace, got "
                f"{type(trace).__name__}; pass stream=True for "
                f"iterables / generator factories")
        if chunk is not None and not stream:
            raise ValueError("chunk is a stream-point knob; pass stream=True")
        self.points.append(Point(trace, sys, mode, bloom, meta,
                                 stream=stream, chunk=chunk))
        return self

    def extend(self, traces: Sequence[Trace], sys: SystemConfig,
               mode: str = "ts", bloom: Optional[tuple] = None,
               metas: Optional[Sequence[dict]] = None) -> "Campaign":
        traces = list(traces)
        metas = [{}] * len(traces) if metas is None else list(metas)
        if len(metas) != len(traces):  # ValueError: survives python -O
            raise ValueError(
                f"metas ({len(metas)}) must match traces ({len(traces)})")
        for tr, m in zip(traces, metas):
            self.add(tr, sys, mode, bloom, **m)
        return self

    def add_policy_grid(self, trace: Trace, sys: SystemConfig,
                        programs: Sequence[PolicyProgram], mode: str = "ts",
                        derive_cost: bool = True, **meta) -> "Campaign":
        """Fan ``trace`` out across a grid of policy programs (one point
        per program; each record carries ``policy=<program name>`` plus
        ``meta``). ``derive_cost=True`` routes through
        ``sys.with_policy`` so each program's decision cost follows its
        length — the ``ts`` vs ``nots`` SMC-slowness experiment;
        ``derive_cost=False`` keeps ``sys``'s cost for bit-comparable
        scheduling-only sweeps."""
        emulator.check_mode(mode)
        names = [p.name for p in programs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(
                f"policy grid needs unique program names (records key "
                f"on them), got duplicates {dupes}")
        for prog in programs:
            sysc = sys.with_policy(prog) if derive_cost \
                else dataclasses.replace(sys, policy=prog)
            self.add(trace, sysc, mode, policy=prog.name, **meta)
        return self

    def __len__(self) -> int:
        return len(self.points)

    def run(self, serial: Optional[bool] = None,
            stream_collect: str = "aggregate") -> List[dict]:
        """Execute every point; one batched call per compile-key group.

        The default path prepares EVERY group up front (executable
        lookups settle on this thread, in group order — compile-cache
        counters are identical to the serial loop) and then runs them
        overlapped across the ``repro.core.executor`` worker pool: the
        host-side padding/packing of group k+1 proceeds while group k
        is inside XLA, and independent groups execute concurrently
        across cores. ``serial=True`` keeps the original in-order
        group loop for A/B (``benchmarks --section executor_speed``);
        the default (None) also falls back to it for single-group
        campaigns or a 1-worker pool. Results are bit-identical either
        way, in ``add`` order: the emulator output dict plus the
        point's ``meta`` entries.

        Stream points (``add(..., stream=True)``) execute through the
        constant-memory window loop as their own tasks on the same
        pool; ``stream_collect`` picks their output shape ('aggregate'
        default — sweeps over unbounded traces should not retain
        per-request arrays; 'full' for exact t_resp/t_issue).
        """
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(self.points):
            groups.setdefault(p.group_key(), []).append(i)

        results: List[Optional[dict]] = [None] * len(self.points)
        tasks: List[Any] = []
        merges = []  # (campaign indices, points, per-group result list)
        for key, idxs in groups.items():
            pts = [self.points[i] for i in idxs]
            p0 = pts[0]
            blooms = None
            if p0.bloom is not None:
                # one shared filter broadcasts; distinct ones stack
                same = all(b.bloom is p0.bloom for b in pts)
                blooms = p0.bloom if same else [p.bloom for p in pts]
            outs: List[Optional[dict]] = [None] * len(pts)
            if p0.stream:
                tasks += emulator.prepare_stream_tasks(
                    [p.trace for p in pts], p0.sys, [p.mode for p in pts],
                    blooms, outs,
                    chunk=p0.chunk or emulator.DEFAULT_STREAM_CHUNK,
                    collect=stream_collect)
            else:
                tasks += emulator.prepare_tasks(
                    [p.trace for p in pts], p0.sys, [p.mode for p in pts],
                    blooms, outs)
            merges.append((idxs, pts, outs))
        executor.execute(tasks, serial=serial)
        for idxs, pts, outs in merges:
            for p, i, out in zip(pts, idxs, outs):
                clash = set(out) & set(p.meta)
                if clash:  # ValueError, not assert: survives python -O
                    raise ValueError(
                        f"meta keys shadow emulator result fields: "
                        f"{sorted(clash)}")
                results[i] = {**out, **p.meta}
        return results

    def n_groups(self) -> int:
        return len({p.group_key() for p in self.points})
