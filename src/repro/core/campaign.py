"""Batched emulation campaigns over (workload x system x mode x technique).

The paper's methodology (Secs. 6-8; PiDRAM / DRAM Bender share it) is
sweep-heavy: one DRAM technique is judged across many workloads, sizes,
system configs, and evaluation modes. Point-at-a-time evaluation pays a
fresh ``jax.jit`` compile of the ``2N+4``-step scan for every sweep
point; a :class:`Campaign` instead collects the whole grid, groups
points by compile key (trace-length bucket, ``SystemConfig``, mode,
Bloom-filter shape), executes each group as ONE vmapped
:func:`repro.core.emulator.run_many` call, and returns tidy per-point
records in submission order.

Usage::

    from repro.core.campaign import Campaign

    c = Campaign()
    for kern, tr in traces_by_kernel.items():
        c.add(tr, JETSON_NANO, mode="ts", workload=kern)
        c.add(tr, JETSON_NANO, mode="ts", bloom=bloom_tuple,
              workload=kern, technique="trcd")
    records = c.run()          # [{workload, technique, exec_cycles, ...}]

Results are bit-identical to looping ``emulator.run`` over the points —
the batch axis only vectorizes the same exact int32 arithmetic — but a
sweep compiles at most once per group and dispatches once per group.
Since PR 5 the groups themselves no longer execute serially either:
``run()`` prepares every group and hands the batch to
``repro.core.executor``, which overlaps host-side packing with device
compute and runs independent groups concurrently (``run(serial=True)``
keeps the old in-order loop for A/B). With more than one local device,
each group's batch axis additionally shards via ``shard_map``
(``emulator.set_sharding``).

Unbounded workloads are one more grid axis: ``add(stream, sys,
stream=True, chunk=...)`` accepts an iterable (or generator factory) of
``Trace`` windows and routes through ``emulator.run_stream_many`` — the
constant-memory chunked-window driver — so technique x workload sweeps
can replay production-scale traces next to padded micro-traces in one
campaign. Stream points group on ``(chunk, sys, mode, bloom-shape)``
with no length bucket at all.

Policy sweeps are one more grid axis: :meth:`Campaign.add_policy_grid`
fans a trace out across a set of :class:`repro.core.smcprog.PolicyProgram`
schedulers. By default (``policy_axis=True``) the programs ride the
runtime policy operand: every program whose packed table fits the same
length bucket shares ONE compile-key group and ONE vmapped dispatch —
256 same-bucket policies are one executable and one device call. The
PR 4 staged-constant path (one compile-key group per distinct program)
stays selectable with ``policy_axis=False`` for A/B.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import emulator, executor
from repro.core.emulator import Trace
from repro.core.smcprog import PolicyProgram
from repro.core.timescale import SystemConfig


@dataclasses.dataclass
class Point:
    """One grid point. ``meta`` is carried through to the result.

    ``stream=True`` marks an unbounded point: ``trace`` is then a
    Trace, an iterable of Trace windows, or a zero-arg callable
    returning one, evaluated through the constant-memory
    ``emulator.run_stream_many`` path in windows of ``chunk``
    requests."""
    trace: Any
    sys: SystemConfig
    mode: str = "ts"
    bloom: Optional[tuple] = None       # (words_u32, k, m_bits)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    stream: bool = False
    chunk: Optional[int] = None         # stream window size (stream only)
    # runtime-operand policy axis (add_policy_grid(policy_axis=True)):
    # the program rides the dispatch as data, sys stays policy-free
    policy: Optional[PolicyProgram] = None
    policy_cost: Optional[int] = None   # smc_cycles_per_decision operand
    # memoized content_digest() — not part of identity/compares
    _digest: Optional[str] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def content_digest(self) -> str:
        """sha1 hex digest of this point's result-relevant content: the
        mode plus every trace array plus bloom words/params (meta is
        excluded — it is re-applied at merge time). Memoized on the
        point, so repeated :func:`_group_digest` calls — a second
        ``Campaign.run(checkpoint=...)``, or the sweep service's
        per-dispatch checkpoint path under load — hash each large trace
        exactly once instead of once per call. Points are treated as
        immutable after ``add``; mutating a trace in place after the
        first digest would go unnoticed (the same assumption the
        executor's ``pack`` closures already make). Stream points have
        no content address (one-shot iterators) and raise."""
        if self.stream:
            raise ValueError(
                "stream points have no content digest (their input is a "
                "one-shot iterator); checkpointing skips them")
        if self._digest is None:
            h = hashlib.sha1()
            h.update(self.mode.encode())
            for f in ("kind", "bank", "row", "delta", "dep"):
                h.update(np.ascontiguousarray(
                    np.asarray(getattr(self.trace, f), np.int32)).tobytes())
            if self.bloom is not None:
                h.update(np.ascontiguousarray(
                    np.asarray(self.bloom[0])).tobytes())
                h.update(repr((int(self.bloom[1]),
                               int(self.bloom[2]))).encode())
            if self.policy is not None:
                # packed table content + cost operand: two points with
                # the same trace but different runtime policies must
                # never share a checkpoint address
                from repro.core.smcprog import pack_program
                h.update(np.ascontiguousarray(
                    pack_program(self.policy)).tobytes())
                h.update(repr(int(self.policy_cost or 0)).encode())
            self._digest = h.hexdigest()
        return self._digest

    def group_key(self) -> tuple:
        # emulator.group_key is the single source of truth for bucket /
        # mode / bloom-shape normalization; slot budget and batch axis
        # are derived per group inside the run_many call
        if self.stream:
            # no length bucket by construction: streamed points group on
            # (chunk, sys, mode, bloom-shape) alone, whatever their size
            chunk = self.chunk or emulator.DEFAULT_STREAM_CHUNK
            return ("stream", chunk, self.sys,
                    emulator._norm_mode(self.mode),
                    emulator._bloom_shape(self.bloom))
        return emulator.group_key(self.trace.n, self.sys, self.mode,
                                  self.bloom, policy=self.policy)


def _group_digest(key: tuple, pts: Sequence[Point]) -> str:
    """Content address of one compile-key group's RESULTS: the group key
    (system config, mode, shapes — policy and fault models included via
    SystemConfig) plus every member point's memoized
    :meth:`Point.content_digest` (mode + trace arrays + bloom words),
    in group order. Two campaigns computing the same digest would
    produce bit-identical ``outs`` for the group — which is what makes
    checkpoint resume safe: a stale or foreign file can only collide by
    content, not by position. The per-point hashing is hoisted into the
    point (one O(trace) hash per point per process, however many
    ``run(checkpoint=...)`` calls or service drain-and-checkpoint
    passes re-derive the group path)."""
    h = hashlib.sha1()
    h.update(repr(key).encode())
    for p in pts:
        h.update(p.content_digest().encode())
    return h.hexdigest()[:16]


def _checkpointed(orig_finalize, outs: List[Optional[dict]], path: str):
    """Wrap a task's ``finalize`` so the group's result list is persisted
    the moment its last slot lands (atomically: tmp + rename — a kill
    mid-write leaves no half file, the group just recomputes). A group
    spanning several tasks saves once, from whichever task finishes
    last; concurrent finalizers can at worst both write identical bytes
    and ``os.replace`` keeps either one whole."""
    def finalize(out, ctx):
        orig_finalize(out, ctx)
        if all(o is not None for o in outs):
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "wb") as fh:
                pickle.dump(outs, fh, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
    return finalize


class Campaign:
    """Collect grid points, execute them in compile-key groups.

    ``add`` order is preserved in ``run()``'s output; extra keyword
    arguments to ``add`` (workload name, technique label, size, ...)
    come back verbatim on each record, which is what makes the output
    tidy-data-friendly for the paper-figure benchmarks.

    ``run(checkpoint=dir)`` persists each completed group's results
    incrementally and resumes a killed sweep with zero recomputation;
    ``run(on_error='quarantine')`` isolates failing grid points instead
    of abandoning the sweep. ``last_run`` reports what happened.
    """

    def __init__(self) -> None:
        self.points: List[Point] = []
        # stats of the most recent run(): group counts by outcome plus
        # the executor's TaskFailure records (empty before any run)
        self.last_run: Dict[str, Any] = {}

    def add(self, trace, sys: SystemConfig, mode: str = "ts",
            bloom: Optional[tuple] = None, stream: bool = False,
            chunk: Optional[int] = None, **meta) -> "Campaign":
        # a real exception, not an assert: grid-driving scripts run
        # under `python -O` too, where asserts vanish silently
        emulator.check_mode(mode)
        if not stream and not isinstance(trace, Trace):
            raise ValueError(
                f"non-stream points need a Trace, got "
                f"{type(trace).__name__}; pass stream=True for "
                f"iterables / generator factories")
        if chunk is not None and not stream:
            raise ValueError("chunk is a stream-point knob; pass stream=True")
        self.points.append(Point(trace, sys, mode, bloom, meta,
                                 stream=stream, chunk=chunk))
        return self

    def extend(self, traces: Sequence[Trace], sys: SystemConfig,
               mode: str = "ts", bloom: Optional[tuple] = None,
               metas: Optional[Sequence[dict]] = None) -> "Campaign":
        traces = list(traces)
        metas = [{}] * len(traces) if metas is None else list(metas)
        if len(metas) != len(traces):  # ValueError: survives python -O
            raise ValueError(
                f"metas ({len(metas)}) must match traces ({len(traces)})")
        for tr, m in zip(traces, metas):
            self.add(tr, sys, mode, bloom, **m)
        return self

    def add_policy_grid(self, trace: Trace, sys: SystemConfig,
                        programs: Sequence[PolicyProgram], mode: str = "ts",
                        derive_cost: bool = True, policy_axis: bool = True,
                        **meta) -> "Campaign":
        """Fan ``trace`` out across a grid of policy programs (one point
        per program; each record carries ``policy=<program name>`` plus
        ``meta``). ``derive_cost=True`` makes each program's decision
        cost follow its length (``sys.with_policy`` semantics) — the
        ``ts`` vs ``nots`` SMC-slowness experiment; ``derive_cost=False``
        keeps ``sys``'s cost for bit-comparable scheduling-only sweeps.

        ``policy_axis=True`` (default) rides the runtime policy operand:
        every program's packed table must fit one shared length bucket
        (``smcprog.table_bucket``), and the whole grid becomes ONE
        compile-key group — one executable, one vmapped dispatch,
        however many programs. Mixed buckets raise (name the offender,
        don't silently fork groups); split the grid by bucket or pass
        ``policy_axis=False`` for the PR 4 staged-constant path (one
        group — one XLA compile — per distinct program)."""
        emulator.check_mode(mode)
        names = [p.name for p in programs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(
                f"policy grid needs unique program names (records key "
                f"on them), got duplicates {dupes}")
        if not isinstance(trace, Trace):
            raise ValueError(
                f"policy grids need a Trace, got {type(trace).__name__}")
        if "policy" in meta:
            raise ValueError(
                "meta key 'policy' is reserved for the program name")
        if not policy_axis:
            for prog in programs:
                sysc = sys.with_policy(prog) if derive_cost \
                    else dataclasses.replace(sys, policy=prog)
                self.add(trace, sysc, mode, policy=prog.name, **meta)
            return self
        from repro.core.smcprog import table_bucket
        buckets = {p.name: table_bucket(p.n_ops) for p in programs}
        lb = min(buckets.values(), default=None)
        for prog in programs:
            if buckets[prog.name] != lb:
                raise ValueError(
                    f"policy_axis=True needs one shared table-length "
                    f"bucket, but program {prog.name!r} ({prog.n_ops} "
                    f"ops) packs to bucket {buckets[prog.name]} while "
                    f"others pack to {lb}; split the grid by bucket or "
                    f"pass policy_axis=False")
        for prog in programs:
            cost = prog.smc_cycles() if derive_cost \
                else int(sys.smc_cycles_per_decision)
            self.points.append(Point(
                trace, sys, mode, None, {"policy": prog.name, **meta},
                policy=prog, policy_cost=cost))
        return self

    def __len__(self) -> int:
        return len(self.points)

    def run(self, serial: Optional[bool] = None,
            stream_collect: str = "aggregate",
            checkpoint: Optional[str] = None,
            on_error: str = "raise",
            timeout: Optional[float] = None,
            retries: Optional[int] = None) -> List[dict]:
        """Execute every point; one batched call per compile-key group.

        The default path prepares EVERY group up front (executable
        lookups settle on this thread, in group order — compile-cache
        counters are identical to the serial loop) and then runs them
        overlapped across the ``repro.core.executor`` worker pool: the
        host-side padding/packing of group k+1 proceeds while group k
        is inside XLA, and independent groups execute concurrently
        across cores. ``serial=True`` keeps the original in-order
        group loop for A/B (``benchmarks --section executor_speed``);
        the default (None) also falls back to it for single-group
        campaigns or a 1-worker pool. Results are bit-identical either
        way, in ``add`` order: the emulator output dict plus the
        point's ``meta`` entries.

        Stream points (``add(..., stream=True)``) execute through the
        constant-memory window loop as their own tasks on the same
        pool; ``stream_collect`` picks their output shape ('aggregate'
        default — sweeps over unbounded traces should not retain
        per-request arrays; 'full' for exact t_resp/t_issue).

        Fault tolerance:

        * ``checkpoint=<dir>`` (e.g. ``artifacts/campaigns/mysweep``)
          persists each completed group's raw result list as
          ``group-<digest>.pkl`` the moment its task finalizes —
          incrementally, not at sweep end — where the digest is the
          group's full content address (:func:`_group_digest`). A rerun
          with the same directory loads finished groups, dispatches
          NOTHING for them, and produces bit-identical final records (a
          killed process resumes for free). Stream groups are never
          checkpointed: their inputs are one-shot iterators with no
          content address.
        * ``on_error='quarantine'`` isolates failures: a raising group
          is recorded (``last_run['failures']``) and its points come
          back as error records (``{'error', 'error_type', 'group',
          **meta}``) while every other group completes normally. The
          default ``'raise'`` raises the executor's aggregate
          :class:`repro.core.executor.ExecutionError` (after completed
          groups checkpointed — a poisoned sweep still makes resumable
          progress).
        * ``timeout`` / ``retries`` pass through to
          :func:`repro.core.executor.execute` (per-dispatch wall bound,
          bounded retry-with-backoff for transient failures).

        ``self.last_run`` gets ``{'groups', 'loaded', 'computed',
        'failed', 'failures'}`` either way.
        """
        if on_error not in ("raise", "quarantine"):
            raise ValueError(
                f"on_error must be 'raise' or 'quarantine', got {on_error!r}")
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(self.points):
            groups.setdefault(p.group_key(), []).append(i)
        if checkpoint is not None:
            os.makedirs(checkpoint, exist_ok=True)

        results: List[Optional[dict]] = [None] * len(self.points)
        tasks: List[Any] = []
        merges = []  # (campaign indices, points, group result list, tasks)
        loaded = 0
        for key, idxs in groups.items():
            pts = [self.points[i] for i in idxs]
            p0 = pts[0]
            ckpt_path = None
            if checkpoint is not None and not p0.stream:
                ckpt_path = os.path.join(
                    checkpoint, f"group-{_group_digest(key, pts)}.pkl")
                if os.path.exists(ckpt_path):
                    with open(ckpt_path, "rb") as fh:
                        outs = pickle.load(fh)
                    if len(outs) == len(pts) and all(
                            o is not None for o in outs):
                        loaded += 1
                        merges.append((idxs, pts, outs, []))
                        continue  # finished group: zero recompute
            blooms = None
            if p0.bloom is not None:
                # one shared filter broadcasts; distinct ones stack
                same = all(b.bloom is p0.bloom for b in pts)
                blooms = p0.bloom if same else [p.bloom for p in pts]
            outs = [None] * len(pts)
            if p0.stream:
                gtasks = emulator.prepare_stream_tasks(
                    [p.trace for p in pts], p0.sys, [p.mode for p in pts],
                    blooms, outs,
                    chunk=p0.chunk or emulator.DEFAULT_STREAM_CHUNK,
                    collect=stream_collect)
            else:
                # policy groups never mix with staged/legacy points
                # (their group_key carries a fifth, policy element)
                pkw = {} if p0.policy is None else dict(
                    policies=[p.policy for p in pts],
                    policy_costs=[p.policy_cost for p in pts])
                gtasks = emulator.prepare_tasks(
                    [p.trace for p in pts], p0.sys, [p.mode for p in pts],
                    blooms, outs, **pkw)
            if ckpt_path is not None:
                for gt in gtasks:
                    gt.finalize = _checkpointed(gt.finalize, outs, ckpt_path)
            tasks += gtasks
            merges.append((idxs, pts, outs, gtasks))

        failures = executor.execute(
            tasks, serial=serial, timeout=timeout, retries=retries,
            raise_on_error=False)
        fail_by_task = {id(f.task): f for f in failures}
        failed_groups = sum(
            1 for m in merges if any(id(t) in fail_by_task for t in m[3]))
        self.last_run = {
            "groups": len(groups), "loaded": loaded,
            "computed": len(groups) - loaded - failed_groups,
            "failed": failed_groups, "failures": failures,
        }
        if failures and on_error == "raise":
            raise executor.ExecutionError(failures)

        for idxs, pts, outs, gtasks in merges:
            gfail = next((fail_by_task[id(t)] for t in gtasks
                          if id(t) in fail_by_task), None)
            for p, i, out in zip(pts, idxs, outs):
                if out is None:
                    # quarantined: the group's task raised (or timed
                    # out) before finalizing this point
                    e = gfail.error if gfail is not None else None
                    results[i] = {
                        "error": str(e) if e is not None else "not computed",
                        "error_type": type(e).__name__ if e is not None
                        else "Unknown",
                        "group": gfail.label if gfail is not None else "",
                        **p.meta}
                    continue
                clash = set(out) & set(p.meta)
                if clash:  # ValueError, not assert: survives python -O
                    raise ValueError(
                        f"meta keys shadow emulator result fields: "
                        f"{sorted(clash)}")
                results[i] = {**out, **p.meta}
        return results

    def n_groups(self) -> int:
        return len({p.group_key() for p in self.points})
