"""Bloom filter over weak DRAM rows (RAIDR-style, Sec. 8.2 of the paper).

Host-built (numpy) from the characterization pass, probed inside the
software memory controller on every row activation. Keys are weak rows,
so a false positive only means a weak-timing row gets *nominal* tRCD —
never an unsafe reduced access. The JAX probe here is the reference; the
Pallas kernel in ``repro.kernels.bloom_probe`` is the TPU-optimized twin.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

_MULS = np.array([0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F, 0x165667B1,
                  0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2D], np.uint32)


def _mix(x: np.ndarray, mul: int) -> np.ndarray:
    x = x.astype(np.uint32)
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(mul)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(13)
    x = (x * np.uint32(0x2B2AE3D5)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(16)
    return x


@dataclasses.dataclass
class BloomFilter:
    bits: np.ndarray       # uint32 words, len = m_bits // 32
    m_bits: int
    k: int

    @staticmethod
    def build(keys: np.ndarray, m_bits: int = 1 << 20, k: int = 4) -> "BloomFilter":
        assert m_bits % 32 == 0 and (m_bits & (m_bits - 1)) == 0
        words = np.zeros(m_bits // 32, np.uint32)
        keys = np.asarray(keys, np.uint32)
        for i in range(k):
            idx = _mix(keys, int(_MULS[i])) & np.uint32(m_bits - 1)
            np.bitwise_or.at(words, idx >> np.uint32(5),
                             np.uint32(1) << (idx & np.uint32(31)))
        return BloomFilter(bits=words, m_bits=m_bits, k=k)

    def contains(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.uint32)
        out = np.ones(keys.shape, bool)
        for i in range(self.k):
            idx = _mix(keys, int(_MULS[i])) & np.uint32(self.m_bits - 1)
            bit = (self.bits[idx >> np.uint32(5)] >> (idx & np.uint32(31))) & np.uint32(1)
            out &= bit.astype(bool)
        return out

    def false_positive_rate(self, probes: np.ndarray, truth: np.ndarray) -> float:
        pos = self.contains(probes)
        fp = pos & ~truth
        denom = max(int((~truth).sum()), 1)
        return float(fp.sum()) / denom


def bloom_probe_jnp(words: jnp.ndarray, m_bits: int, k: int, keys: jnp.ndarray):
    """Pure-jnp probe (emulator + kernel oracle). keys: uint32 [N] -> bool [N]."""
    keys = keys.astype(jnp.uint32)
    out = jnp.ones(keys.shape, bool)
    for i in range(k):
        x = keys
        x = x ^ (x >> 16)
        x = x * jnp.uint32(int(_MULS[i]))
        x = x ^ (x >> 13)
        x = x * jnp.uint32(0x2B2AE3D5)
        x = x ^ (x >> 16)
        idx = x & jnp.uint32(m_bits - 1)
        bit = (words[idx >> 5] >> (idx & 31)) & 1
        out = out & bit.astype(bool)
    return out
