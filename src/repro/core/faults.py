"""Deterministic DRAM fault injection: retention weak cells + RowHammer.

EasyDRAM's ecosystem (SoftMC, DRAM Bender — see PAPERS.md) exists to
characterize real-chip *misbehavior*: retention failures and RowHammer
disturbance flips. This module gives the emulation core the same
vocabulary. A :class:`FaultModel` describes an error process with plain
integers only, so it is hashable and rides the emulator compile key
through ``SystemConfig.faults`` exactly like a policy program — fault
configs group correctly in :class:`repro.core.campaign.Campaign`, and
``faults=None`` leaves compile keys (and the compiled programs — the
fault carry is an empty pytree then) byte-identical to a fault-free
build.

Two error processes, both evaluated per served request inside the scan
slot body at O(1) + O(n_banks) cost (point gathers/scatters and one
bank-width vector op — never O(rows) state, preserving the engine's
O(Q)+O(1) per-slot invariant):

* **RowHammer** — each row ACT increments its bank's aggressor
  activation counter; an all-bank REF (the existing tREFI catch-up in
  ``dram.service_request``) resets every counter, and a policy-driven
  neighbor refresh (see ``mitigate`` below) resets the served bank's.
  When a bank's counter crosses ``hammer_threshold`` on an ACT, the
  activated row is the aggressor and its two physical neighbors
  (row ± 1) each receive an independent Bernoulli(``hammer_flip_fp`` /
  65536) bit-flip draw, after which the counter resets (the aggressor
  pattern must be rebuilt). Per-bank counters are a deliberate
  simplification of per-row ones: the O(rows) table a real TRR keeps is
  exactly the state the slot invariant forbids, and for the
  single-aggressor storms the study sweeps the bank counter IS the
  aggressor count.
* **Retention** — a stateless weak-cell map: each (bank, row) is weak
  with probability ``weak_fp`` / 65536 (decided by a content-keyed hash,
  not a stored table), and a READ of a weak row flips when the time
  since the row's last all-bank REF window start exceeds
  ``retention_ticks`` (``t % tREFI >= retention_ticks`` — the existing
  refresh model already quantizes REFs to tREFI boundaries).

Determinism is the contract: every random draw is a pure function of
``(seed, bank, row, absolute DRAM time)`` via ``jax.random.fold_in``
chains — no carried RNG state — so the flip set is bit-identical across
``run`` == ``run_many`` == ``run_ref`` == ``run_stream`` == sharded
execution (frozen streaming slots have ``do=False`` and draw nothing;
window shifts never touch the fault carry, which holds no request
indices). Pinned in tests/test_faults.py.

Flip *events* are recorded in a bounded victim log (``victim_slots``
entries of (bank, row, tick)); total flip counts keep counting past the
log's capacity. Fault state lives in ``EmulatorState.faults`` (a plain
dict pytree) and the same :func:`apply_slot` is called by both engine
cores, so the semantics cannot drift between them.

Mitigations are *policies*: ``smcprog`` programs gain a ``mitigate``
output (see :func:`repro.core.smcprog.PolicyBuilder.build`) plus two
environment loads — ``hammer_count()`` (the served bank's aggressor
counter) and ``para_rand()`` (a per-slot uniform draw) — which express
counter-based TRR and PARA-style probabilistic neighbor refresh in the
policy IR. When the mitigate flag fires on a served request the engine
charges a neighbor-refresh row cycle to the bank
(``dram.neighbor_refresh_ticks``) and resets its aggressor counter:
the bit-error-rate vs. slowdown tradeoff falls out end-to-end
(``techniques.RowHammerMitigationStudy``).

No module-level jnp constants: like ``smcprog``, this module is
imported by the jax-free config layer (timescale.py) and must not
initialize the JAX backend at import time.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# fold_in stream tags: disjoint randomness domains under one user seed
_DOMAIN_HAMMER = 1
_DOMAIN_WEAK = 2
_DOMAIN_PARA = 3

_FP_ONE = 65536  # probability fixed-point denominator (16-bit)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One deterministic DRAM error process, all-integer and hashable
    (rides the emulator compile key through ``SystemConfig.faults``).

    Probabilities are 16-bit fixed point: ``x / 65536`` (65536 = always,
    0 = never). ``hammer_threshold == 0`` disables the RowHammer model;
    ``weak_fp == 0`` disables the retention model — disabled models
    stage zero extra randomness ops."""
    seed: int = 0
    # RowHammer: per-bank ACT counter threshold and per-victim flip prob
    hammer_threshold: int = 0
    hammer_flip_fp: int = _FP_ONE
    # retention: weak-cell fraction and decay time after a REF boundary
    weak_fp: int = 0
    retention_ticks: int = 0
    # bounded victim-event log capacity (counts keep going past it)
    victim_slots: int = 32

    def validate(self) -> "FaultModel":
        if self.hammer_threshold < 0:
            raise ValueError(
                f"hammer_threshold must be >= 0, got {self.hammer_threshold}")
        for nm in ("hammer_flip_fp", "weak_fp"):
            v = getattr(self, nm)
            if not 0 <= v <= _FP_ONE:
                raise ValueError(
                    f"{nm} is 16-bit fixed point in [0, {_FP_ONE}], got {v}")
        if self.retention_ticks < 0:
            raise ValueError(
                f"retention_ticks must be >= 0, got {self.retention_ticks}")
        if self.victim_slots < 1:
            raise ValueError(
                f"victim_slots must be >= 1, got {self.victim_slots}")
        return self


def init_fault_state(fm: FaultModel, n_banks: int) -> dict:
    """Fresh fault carry for one trace: the per-bank aggressor counters,
    the bounded victim log (-1 = empty), and the flip/mitigation
    counters. ``vptr`` is the total flip count (it keeps incrementing
    past ``victim_slots``; log writes just stop)."""
    V = int(fm.victim_slots)
    return {
        "hct": jnp.zeros((n_banks,), jnp.int32),
        "vbank": jnp.full((V,), -1, jnp.int32),
        "vrow": jnp.full((V,), -1, jnp.int32),
        "vt": jnp.full((V,), -1, jnp.int32),
        "vptr": jnp.int32(0),
        "ham_flips": jnp.int32(0),
        "ret_flips": jnp.int32(0),
        "mitigations": jnp.int32(0),
    }


def _u16(key) -> jnp.ndarray:
    """Uniform 16-bit draw from one derived key (compare against a
    ``*_fp`` threshold: ``_u16(k) < fp`` fires with prob fp/65536)."""
    return (jax.random.bits(key, (), jnp.uint32) >> 16).astype(jnp.int32)


def para_draw(seed: int, q_bank, q_row, now) -> jnp.ndarray:
    """[Q] per-slot uniform 16-bit draws for the ``para_rand`` policy
    load: a pure content hash of (seed, bank, row, decision-time DRAM
    frontier), so PARA mitigation decisions are bit-identical across
    engines, batching, streaming, and sharding."""
    kp = jax.random.fold_in(jax.random.PRNGKey(seed), _DOMAIN_PARA)
    kt = jax.random.fold_in(kp, now)

    def one(b, r):
        return _u16(jax.random.fold_in(jax.random.fold_in(kt, b), r))

    return jax.vmap(one)(q_bank, q_row)


def apply_slot(fm: FaultModel, n_rows: int, tREFI: int, mit_ticks: int,
               fstate: dict, *, do, hit, bank, row, kind, t_start,
               refreshed, mitigate):
    """Advance the fault carry for one scheduling slot. Shared verbatim
    by the fast core (:func:`repro.core.emulator._make_slot_body`), the
    reference core (``_run_core_ref``) and — through the shared slot
    body — the streaming windows, which is what makes the flip sets
    engine-invariant by construction.

    ``do``/``hit`` are the slot's serve/row-hit predicates, ``bank`` /
    ``row`` / ``kind`` the served request, ``t_start`` its absolute
    DRAM-tick service time, ``refreshed`` whether this service caught up
    on all-bank REF debt, and ``mitigate`` the policy's neighbor-refresh
    flag for the served request (None = the policy has no mitigate
    output). Returns ``(new_fstate, extra_bank_ticks)`` where the extra
    ticks are the mitigation's row-cycle cost on the served bank (0 when
    no mitigation fired). Everything is a predicated point gather /
    scatter plus one n_banks-wide reset — O(1)+O(n_banks) per slot, no
    O(rows) state."""
    from repro.core.dram import READ

    kh = jax.random.fold_in(jax.random.PRNGKey(fm.seed), _DOMAIN_HAMMER)
    kw = jax.random.fold_in(jax.random.PRNGKey(fm.seed), _DOMAIN_WEAK)
    mit = jnp.zeros((), bool) if mitigate is None else (mitigate & do)

    # all-bank REF wipes accumulated disturbance in every bank (the REF
    # catch-up in dram.service_request runs BEFORE the access, so reset
    # precedes this slot's own ACT increment)
    hct = jnp.where(refreshed, 0, fstate["hct"])
    events = []  # (flip predicate, victim row, is_hammer)
    if fm.hammer_threshold > 0:
        act = do & ~hit                      # row activate happened
        cur = hct[bank] + act.astype(jnp.int32)
        crossed = act & (cur >= fm.hammer_threshold)
        kt = jax.random.fold_in(
            jax.random.fold_in(kh, bank), t_start)
        for off in (-1, 1):                  # the two physical neighbors
            vr = row + off
            valid = (vr >= 0) & (vr < n_rows)
            u = _u16(jax.random.fold_in(kt, vr))
            events.append((crossed & valid & (u < fm.hammer_flip_fp),
                           vr, True))
        # crossing consumed the disturbance; a fired mitigation refreshed
        # the bank's victims and resets it too
        hct = hct.at[bank].set(
            jnp.where(do, jnp.where(crossed | mit, 0, cur), hct[bank]))
    if fm.weak_fp > 0:
        kc = jax.random.fold_in(jax.random.fold_in(kw, bank), row)
        weak = _u16(kc) < fm.weak_fp        # stateless weak-cell map
        decayed = (t_start % tREFI) >= fm.retention_ticks
        events.append((do & (kind == READ) & weak & decayed, row, False))

    vbank, vrow = fstate["vbank"], fstate["vrow"]
    vt, vptr = fstate["vt"], fstate["vptr"]
    ham = jnp.int32(0)
    ret = jnp.int32(0)
    V = int(fm.victim_slots)
    for pred, r, is_ham in events:
        i = jnp.clip(vptr, 0, V - 1)
        can = pred & (vptr < V)              # log is bounded; counts aren't
        vbank = vbank.at[i].set(jnp.where(can, bank, vbank[i]))
        vrow = vrow.at[i].set(jnp.where(can, r, vrow[i]))
        vt = vt.at[i].set(jnp.where(can, t_start, vt[i]))
        vptr = vptr + pred.astype(jnp.int32)
        if is_ham:
            ham = ham + pred.astype(jnp.int32)
        else:
            ret = ret + pred.astype(jnp.int32)

    new = {
        "hct": hct, "vbank": vbank, "vrow": vrow, "vt": vt, "vptr": vptr,
        "ham_flips": fstate["ham_flips"] + ham,
        "ret_flips": fstate["ret_flips"] + ret,
        "mitigations": fstate["mitigations"] + mit.astype(jnp.int32),
    }
    return new, jnp.where(mit, jnp.int32(mit_ticks), jnp.int32(0))


def fault_result_fields(fstate: dict) -> dict:
    """Per-trace result entries derived from a final fault carry — one
    source of truth for the single-shot cores and the streaming
    finalizer (tests compare these across all engines)."""
    return {
        "flips": fstate["vptr"],
        "ham_flips": fstate["ham_flips"],
        "ret_flips": fstate["ret_flips"],
        "mitigations": fstate["mitigations"],
        "victim_bank": fstate["vbank"],
        "victim_row": fstate["vrow"],
        "victim_t": fstate["vt"],
    }
