"""Time scaling: emulation domains, counters, and system configuration.

The paper's mechanism (Sec. 4.3): the modeled system is split into
emulation domains — processor(s), software memory controller (SMC), DRAM
— each with a cycle counter. The engine clock-gates the processor domain
while the SMC is in *critical mode* and releases it by advancing the MC
counter with the *emulated-system* service time (not the FPGA-real time
the slow SMC actually took). Responses carry a consume-tag (processor
cycle) so a processor never observes data earlier than the modeled
system would deliver it.

``SystemConfig`` carries both the modeled system's clocks and the FPGA
platform's clocks, so one engine expresses all three evaluation modes:

* ``ts``        — time scaling ON: emulated time uses f_proc_emu + the
                  modeled HW-MC latency; SMC slowness is invisible.
* ``nots``      — PiDRAM-style: the processor free-runs at f_proc_fpga in
                  FPGA-real time, so SMC slowness and the clock-ratio
                  mismatch leak into results (the inaccuracy the paper
                  quantifies at ~20x).
* ``reference`` — the Sec. 6 RTL reference: a hardware MC at the modeled
                  clock; used to validate ts to <0.1%.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.dram import TCK_NS, Geometry, Timing
from repro.core.faults import FaultModel
from repro.core.smcprog import PolicyProgram


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    # modeled (emulated) system — defaults mirror the Jetson Nano / A57 target
    f_proc_emu_ghz: float = 1.43
    hwmc_latency_ns: float = 20.0      # modeled hardware-MC pipeline latency
    hwmc_issue_ns: float = 2.0         # modeled HW-MC decision (issue) rate
    # FPGA platform
    f_proc_fpga_mhz: float = 50.0
    f_mc_fpga_mhz: float = 100.0
    smc_cycles_per_decision: int = 400  # SMC instructions per scheduling decision
    smc_transfer_cycles: int = 120      # request/command buffer transfer overhead
    # processor model
    window: int = 4                     # max outstanding requests (MLP)
    # DRAM
    timing: Timing = dataclasses.field(default_factory=Timing)
    geometry: Geometry = dataclasses.field(default_factory=Geometry)
    scheduler: str = "frfcfs"           # frfcfs | fcfs (legacy string path)
    # software-defined scheduling: a repro.core.smcprog.PolicyProgram
    # evaluated inside the scan slot body. When set it REPLACES the
    # `scheduler` flag for the scheduling decision; it is content-hashed,
    # so it folds into the emulator compile key / Campaign grouping
    # through this config. Attach via with_policy() to also derive the
    # decision cost from program length, or dataclasses.replace() to
    # keep this config's cost (what the bit-identity tests do).
    policy: Optional[PolicyProgram] = None
    # deterministic DRAM error injection: a repro.core.faults.FaultModel
    # (all-int, hashable) evaluated inside the scan slot body. None means
    # a perfect memory AND a byte-identical compiled program (the fault
    # carry is an empty pytree then). Like `policy`, it folds into the
    # emulator compile key / Campaign grouping through this config.
    faults: Optional[FaultModel] = None

    # ---- derived conversion helpers (proc cycles per DRAM tick etc.) ----
    @property
    def proc_per_tick_emu(self) -> float:
        return self.f_proc_emu_ghz * TCK_NS

    @property
    def proc_per_tick_fpga(self) -> float:
        return self.f_proc_fpga_mhz * 1e-3 * TCK_NS

    @property
    def hwmc_latency_proc(self) -> int:
        return int(round(self.hwmc_latency_ns * self.f_proc_emu_ghz))

    @property
    def hwmc_issue_proc(self) -> int:
        return max(int(round(self.hwmc_issue_ns * self.f_proc_emu_ghz)), 1)

    @property
    def smc_latency_fpga_proc(self) -> int:
        """SMC decision latency as seen by a free-running FPGA processor."""
        fpga_ns = (self.smc_cycles_per_decision + self.smc_transfer_cycles) \
            / (self.f_mc_fpga_mhz * 1e-3)
        return int(round(fpga_ns * self.f_proc_fpga_mhz * 1e-3))

    def with_policy(self, prog: PolicyProgram) -> "SystemConfig":
        """Attach a policy program AND derive the SMC decision cost from
        its length (``prog.smc_cycles()`` — the modeled software-MC
        slowness that time scaling hides and ``nots`` exposes)."""
        return dataclasses.replace(self, policy=prog,
                                   smc_cycles_per_decision=prog.smc_cycles())

    def with_faults(self, fm: Optional[FaultModel]) -> "SystemConfig":
        """Attach (or clear, with None) a deterministic fault model."""
        return dataclasses.replace(
            self, faults=fm.validate() if fm is not None else None)

    def dram_ticks_to_proc(self, ticks, mode: str):
        if mode == "nots":
            return ticks * self.proc_per_tick_fpga
        return ticks * self.proc_per_tick_emu

    def cycles_to_seconds(self, cycles, mode: str) -> float:
        hz = (self.f_proc_fpga_mhz * 1e6) if mode == "nots" \
            else (self.f_proc_emu_ghz * 1e9)
        return float(cycles) / hz


JETSON_NANO = SystemConfig()

# PiDRAM-style platform: 50 MHz in-order core + RTL (fast) memory
# controller, no time scaling -> the clock-ratio skew the paper measures
PIDRAM_LIKE = SystemConfig(f_proc_fpga_mhz=50.0, window=1,
                           smc_cycles_per_decision=0, smc_transfer_cycles=0)

VALIDATION_1GHZ = SystemConfig(f_proc_emu_ghz=1.0, f_proc_fpga_mhz=100.0,
                               f_mc_fpga_mhz=100.0)
