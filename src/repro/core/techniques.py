"""DRAM techniques as software-memory-controller extensions (Secs. 7-8).

Each technique is ~100 lines of plain Python/JAX over the engine — the
paper's accessibility claim, reproduced. ``RowClone`` handles the four
allocation constraints (alignment / granularity / subarray mapping /
coherence) with profiling-driven fallback; ``TRCDReduction`` runs the
two-stage characterize -> Bloom-filter flow and hands the filter to the
engine, which consults it on every row activation;
``SchedulingPolicyStudy`` sweeps software-defined scheduler programs
(``repro.core.smcprog``) across workloads with length-derived SMC costs;
``RowHammerMitigationStudy`` sweeps mitigation programs x hammer
intensities under the fault-injection model (``repro.core.faults``),
trading bit-error rate against emulated slowdown.

Evaluation goes through the batched campaign path
(``emulator.run_many`` / ``campaign.Campaign``): ``evaluate_batch`` /
``evaluate_traces`` sweep many sizes or workloads with one compile and
one dispatch per compile-key group; the single-point ``evaluate`` /
``evaluate_trace`` are thin wrappers over a batch of one pair.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import smcprog, traces
from repro.core.campaign import Campaign, Point
from repro.core.bloom import BloomFilter
from repro.core.dram import Geometry
from repro.core.faults import FaultModel
from repro.core.profiling import DeviceModel
from repro.core.smcprog import PolicyProgram
from repro.core.timescale import SystemConfig


@dataclasses.dataclass
class RowCloneResult:
    mode: str
    setting: str
    n_bytes: int
    exec_cycles: int
    exec_seconds: float
    fallback_rows: int
    speedup_vs_cpu: float = 0.0


class RowClone:
    """In-DRAM bulk copy/initialization (Sec. 7)."""

    def __init__(self, sys: SystemConfig, device: Optional[DeviceModel] = None):
        self.sys = sys
        self.geo = sys.geometry
        self.device = device or DeviceModel(self.geo)

    def evaluate(self, n_bytes: int, workload: str = "copy",
                 setting: str = "noflush", mode_ts: str = "ts",
                 cpu_line_delta: int = None):
        """Returns {'cpu': RowCloneResult, 'rowclone': RowCloneResult}.

        cpu_line_delta models the per-line instruction cost of the
        *modeled* CPU's copy loop (a 3-wide OoO core with 64B NEON moves
        retires far fewer cycles/line than a 50 MHz single-issue rv64)."""
        return self.evaluate_batch([n_bytes], workload, setting, mode_ts,
                                   cpu_line_delta)[0]

    def evaluate_batch(self, sizes: Sequence[int], workload: str = "copy",
                       setting: str = "noflush", mode_ts: str = "ts",
                       cpu_line_delta: int = None) -> List[dict]:
        """Sweep ``sizes`` in one batched campaign: all (cpu, rowclone)
        trace pairs run through a single ``run_many`` call per
        compile-key group — one compile and one dispatch per (bucket,
        slot-budget) group, with the short RowClone traces paying only
        their exact slot budget rather than the CPU arm's. Returns one
        {'cpu': ..., 'rowclone': ...} dict per size, in order."""
        gen = traces.copy_workload if workload == "copy" else traces.init_workload
        kw = {} if cpu_line_delta is None else {"cpu_line_delta": cpu_line_delta}
        sizes = list(sizes)
        c = Campaign()
        fallbacks = {}
        for j, nb in enumerate(sizes):  # positional index: duplicate sizes
            for arm in ("cpu", "rowclone"):   # stay independent evaluations
                tr, meta = gen(nb, self.geo, mode=arm, device=self.device,
                               setting=setting, **kw)
                c.add(tr, self.sys, mode=mode_ts, j=j, arm=arm)
                fallbacks[(j, arm)] = meta["fallback_rows"]
        recs = {(r["j"], r["arm"]): r for r in c.run()}
        out = []
        for j, nb in enumerate(sizes):
            d = {}
            for arm in ("cpu", "rowclone"):
                r = recs[(j, arm)]
                d[arm] = RowCloneResult(
                    mode=arm, setting=setting, n_bytes=nb,
                    exec_cycles=int(r["exec_cycles"]),
                    exec_seconds=r["exec_seconds"],
                    fallback_rows=fallbacks[(j, arm)])
            d["rowclone"].speedup_vs_cpu = \
                d["cpu"].exec_cycles / max(d["rowclone"].exec_cycles, 1)
            out.append(d)
        return out


class SchedulingPolicyStudy:
    """Scheduling policies as software — the paper's first key idea,
    turned into a technique-style sweep. A study takes a grid of
    :class:`~repro.core.smcprog.PolicyProgram` schedulers (default: all
    built-ins) and evaluates every (trace x policy x mode) point through
    one batched :class:`Campaign` — one compiled executable and one
    dispatch per program group.

    Two cost treatments, matching the paper's ts/nots axis:

    * ``derive_cost=True`` (default) — each program's SMC decision cost
      follows its length (``with_policy``), so ``nots`` records expose
      how a longer policy program slows the free-running system while
      ``ts`` records stay invariant to it (time scaling hides SMC
      slowness — the claim itself).
    * ``derive_cost=False`` — all programs keep ``sys``'s cost; results
      isolate pure scheduling quality.
    """

    def __init__(self, sys: SystemConfig,
                 programs: Optional[Sequence[PolicyProgram]] = None,
                 baseline: str = "frfcfs"):
        self.sys = sys
        self.programs = list(programs) if programs is not None \
            else list(smcprog.builtin_programs().values())
        if not self.programs:
            raise ValueError("need at least one policy program")
        names = [p.name for p in self.programs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(
                f"program names must be unique (results key on them), "
                f"got duplicates {dupes}")
        self.baseline = baseline

    def evaluate_traces(self, trs: Sequence, mode: str = "ts",
                        derive_cost: bool = True,
                        policy_axis: bool = True) -> List[Dict]:
        """Returns one dict per trace, in input order:
        ``{policy_name: {exec_cycles, row_hits, smc_cycles,
        speedup_vs_baseline}}``. ``policy_axis=True`` (default) rides
        the runtime policy operand — the whole program grid shares one
        compiled executable and one dispatch per trace-length bucket;
        ``policy_axis=False`` keeps the staged-constant path (one
        compile per program). Results are bit-identical either way."""
        c = Campaign()
        for i, tr in enumerate(trs):
            c.add_policy_grid(tr, self.sys, self.programs, mode=mode,
                              derive_cost=derive_cost,
                              policy_axis=policy_axis, i=i)
        recs = {(r["i"], r["policy"]): r for r in c.run()}
        cost = {p.name: p.smc_cycles() if derive_cost
                else self.sys.smc_cycles_per_decision for p in self.programs}
        out: List[Dict] = []
        for i in range(len(trs)):
            d = {}
            base = None
            if any(p.name == self.baseline for p in self.programs):
                base = int(recs[(i, self.baseline)]["exec_cycles"])
            for p in self.programs:
                r = recs[(i, p.name)]
                e = int(r["exec_cycles"])
                d[p.name] = {
                    "exec_cycles": e,
                    "row_hits": int(r["row_hits"]),
                    "smc_cycles": cost[p.name],
                    "speedup_vs_baseline":
                        (base / max(e, 1)) if base is not None else 1.0,
                }
            out.append(d)
        return out


class RowHammerMitigationStudy:
    """RowHammer mitigations as software-memory-controller programs,
    judged end-to-end under the fault-injection model (PR 8): each
    (mitigation program x hammer intensity) point replays a
    :func:`traces.rowhammer_trace` aggressor storm under one
    :class:`~repro.core.faults.FaultModel`, and the record pairs the
    resulting bit-error rate with the mitigation's emulated slowdown —
    the reliability-vs-performance tradeoff curve the paper's
    methodology exists to measure quickly.

    Programs default to :func:`smcprog.mitigation_programs`:
    ``frfcfs`` (no mitigation — the BER ceiling and the slowdown
    baseline), ``para`` (probabilistic neighbor refresh on row-miss
    activations) and ``trr`` (activation-counter-triggered refresh).
    ``derive_cost=True`` additionally charges each program's SMC
    decision cost by its length, so the slowdown axis includes the
    software controller overhead, not just the injected neighbor
    refreshes."""

    def __init__(self, sys: SystemConfig,
                 fault_model: Optional[FaultModel] = None,
                 programs: Optional[Dict[str, PolicyProgram]] = None,
                 baseline: str = "frfcfs"):
        self.sys = sys
        self.geo = sys.geometry
        self.fault_model = fault_model if fault_model is not None else \
            FaultModel(seed=7, hammer_threshold=48, hammer_flip_fp=52000)
        # default arms are tuned TO the fault model: TRR must trigger
        # below the hammer threshold or it never fires, and PARA at ~5%
        # per activation meaningfully resets a threshold-48 counter
        self.programs = dict(programs) if programs is not None \
            else smcprog.mitigation_programs(
                para_fp=3277,
                trr_threshold=max(1, self.fault_model.hammer_threshold // 2))
        if baseline not in self.programs:
            raise ValueError(
                f"baseline {baseline!r} not among programs "
                f"{sorted(self.programs)}")
        self.baseline = baseline

    def evaluate(self, intensities: Sequence[float] = (0.45, 0.9),
                 n_requests: int = 480, mode: str = "ts", seed: int = 0,
                 derive_cost: bool = True, policy_axis: bool = True,
                 **run_kw) -> List[dict]:
        """One record per intensity, in order: ``{'intensity': f,
        <program>: {bit_error_rate, flips, mitigations, exec_cycles,
        exec_seconds, slowdown_vs_unmitigated}}``. All points run as one
        batched campaign. ``policy_axis=True`` (default) carries each
        mitigation program as a runtime operand, so every (program x
        intensity) point sharing a table-length bucket shares ONE
        compiled executable and dispatch; ``policy_axis=False`` keeps
        the staged path (one compile per program). ``run_kw`` passes
        through to :meth:`Campaign.run` (``checkpoint=...`` resumes a
        killed sweep)."""
        import dataclasses as _dc
        c = Campaign()
        sysf = self.sys.with_faults(self.fault_model)
        for i, inten in enumerate(intensities):
            tr = traces.rowhammer_trace(n_requests, self.geo,
                                        intensity=float(inten),
                                        seed=seed + i)
            for name, prog in self.programs.items():
                if policy_axis:
                    cost = prog.smc_cycles() if derive_cost \
                        else int(self.sys.smc_cycles_per_decision)
                    # direct Point append: the dict key (not prog.name)
                    # labels the record, and mixed table buckets simply
                    # fork into per-bucket groups here
                    c.points.append(Point(
                        tr, sysf, mode, None, {"mitigation": name, "i": i},
                        policy=prog, policy_cost=cost))
                    continue
                sysc = self.sys.with_policy(prog) if derive_cost \
                    else _dc.replace(self.sys, policy=prog)
                c.add(tr, sysc.with_faults(self.fault_model), mode,
                      mitigation=name, i=i)
        recs = {(r["i"], r["mitigation"]): r for r in c.run(**run_kw)}
        out: List[dict] = []
        for i, inten in enumerate(intensities):
            base = int(recs[(i, self.baseline)]["exec_cycles"])
            d: dict = {"intensity": float(inten)}
            for name in self.programs:
                r = recs[(i, name)]
                d[name] = {
                    "bit_error_rate": float(r["bit_error_rate"]),
                    "flips": int(r["flips"]),
                    "mitigations": int(r["mitigations"]),
                    "exec_cycles": int(r["exec_cycles"]),
                    "exec_seconds": float(r["exec_seconds"]),
                    "slowdown_vs_unmitigated":
                        int(r["exec_cycles"]) / max(base, 1),
                }
            out.append(d)
        return out


class TRCDReduction:
    """Reduced-tRCD access via characterization + Bloom filter (Sec. 8)."""

    def __init__(self, sys: SystemConfig, device: Optional[DeviceModel] = None,
                 m_bits: int = 1 << 20, k: int = 4):
        self.sys = sys
        self.geo = sys.geometry
        self.device = device or DeviceModel(self.geo)
        self.m_bits = m_bits
        self.k = k
        self._bloom: Optional[BloomFilter] = None

    def characterize(self) -> BloomFilter:
        """Stage 1+2: profile rows (device model = the profiling requests'
        results), key the Bloom filter with weak rows."""
        weak = self.device.weak_rows()
        self._bloom = BloomFilter.build(weak, m_bits=self.m_bits, k=self.k)
        return self._bloom

    @property
    def bloom_tuple(self):
        if self._bloom is None:
            self.characterize()
        b = self._bloom
        return (b.bits, b.k, b.m_bits)

    def safety_check(self, n=100000, seed=1):
        """A false positive must map weak->nominal only: verify no weak row
        ever probes negative (zero false negatives by construction)."""
        weak = self.device.weak_rows()
        assert self._bloom is not None
        miss = (~self._bloom.contains(weak)).sum()
        rng = np.random.RandomState(seed)
        probe = rng.randint(0, self.geo.n_banks * self.geo.n_rows, n)
        truth = self.device.weak.reshape(-1)[probe]
        fpr = self._bloom.false_positive_rate(probe, truth)
        return {"false_negatives": int(miss), "false_positive_rate": float(fpr)}

    def evaluate_trace(self, trace, mode_ts: str = "ts"):
        """Run a workload with and without reduced-tRCD scheduling."""
        return self.evaluate_traces([trace], mode_ts)[0]

    def evaluate_traces(self, trs: Sequence, mode_ts: str = "ts") -> List[dict]:
        """Batched base-vs-reduced sweep: every trace is evaluated with
        and without the Bloom filter through one Campaign (one compile
        per (bucket, bloom-presence) group). Returns per-trace dicts in
        input order."""
        bloom = self.bloom_tuple
        c = Campaign()
        for i, tr in enumerate(trs):
            c.add(tr, self.sys, mode=mode_ts, i=i, arm="base")
            c.add(tr, self.sys, mode=mode_ts, bloom=bloom, i=i, arm="reduced")
        arms = {(r["i"], r["arm"]): int(r["exec_cycles"]) for r in c.run()}
        return [{
            "base_cycles": arms[(i, "base")],
            "reduced_cycles": arms[(i, "reduced")],
            "speedup": arms[(i, "base")] / max(arms[(i, "reduced")], 1),
        } for i in range(len(trs))]
