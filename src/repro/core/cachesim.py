"""Set-associative LLC model: turns CPU address streams into DRAM traces.

The modeled system (Jetson-Nano-flavored) has a 512 KiB 8-way LLC with
64 B lines (the paper's EasyDRAM config). Vectorized-enough numpy LRU;
traces here are bounded (<= a few hundred K accesses) so this is fast.
"""
from __future__ import annotations

import numpy as np


class LLC:
    def __init__(self, size_bytes=512 * 1024, ways=8, line=64):
        self.line = line
        self.ways = ways
        self.sets = size_bytes // (ways * line)
        self.tags = np.full((self.sets, ways), -1, np.int64)
        self.lru = np.zeros((self.sets, ways), np.int64)
        self.dirty = np.zeros((self.sets, ways), bool)
        self.tick = 0

    def access(self, addr: int, is_write: bool):
        """Returns (miss, writeback_addr or -1)."""
        self.tick += 1
        lineaddr = addr // self.line
        s = lineaddr % self.sets
        tag = lineaddr // self.sets
        row = self.tags[s]
        hit = np.nonzero(row == tag)[0]
        if hit.size:
            w = hit[0]
            self.lru[s, w] = self.tick
            if is_write:
                self.dirty[s, w] = True
            return False, -1
        w = int(np.argmin(self.lru[s]))
        wb = -1
        if self.tags[s, w] >= 0 and self.dirty[s, w]:
            wb = int((self.tags[s, w] * self.sets + s) * self.line)
        self.tags[s, w] = tag
        self.lru[s, w] = self.tick
        self.dirty[s, w] = is_write
        return True, wb

    def flush_line(self, addr: int):
        """CLFLUSH: returns writeback addr or -1; invalidates the line."""
        lineaddr = addr // self.line
        s = lineaddr % self.sets
        tag = lineaddr // self.sets
        hit = np.nonzero(self.tags[s] == tag)[0]
        if not hit.size:
            return -1
        w = hit[0]
        wb = int(addr) if self.dirty[s, w] else -1
        self.tags[s, w] = -1
        self.dirty[s, w] = False
        return wb


def filter_stream(addrs, writes, llc: LLC = None):
    """Run an address stream through the LLC; return DRAM-level accesses
    as (addr, is_write) arrays (misses + writebacks)."""
    llc = llc or LLC()
    out_a, out_w = [], []
    for a, w in zip(addrs, writes):
        miss, wb = llc.access(int(a), bool(w))
        if wb >= 0:
            out_a.append(wb)
            out_w.append(True)
        if miss:
            out_a.append(int(a))
            out_w.append(False)
    return np.asarray(out_a, np.int64), np.asarray(out_w, bool), llc
