"""DRAM geometry + timing model (DDR4-flavored), all times in DRAM ticks.

One tick = one DRAM command-clock cycle (0.833 ns at DDR4-2400). Using
int32 ticks keeps the whole emulator exact (no float drift) — 2^31 ticks
= 1.8 s of DRAM time, far beyond any emulated workload here.

``BankState`` is the vectorized per-bank timing state machine that the
command-batch executor (our DRAM-Bender analogue) advances. The paper's
SMC prepares command batches; :func:`service_request` computes the exact
DRAM time to serve one request given the current bank state, honoring
tRCD/tRP/tRAS/tCL/tWR/tBL + refresh, with technique hooks (reduced tRCD,
RowClone sequences).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

TCK_NS = 0.833  # DDR4-2400


@dataclasses.dataclass(frozen=True)
class Timing:
    tRCD: int = 17          # 13.5 ns nominal (paper's module, Micron EDY4016A)
    tRCD_reduced: int = 11  # 9.0 ns — strong-row access (Solar-DRAM style)
    tCL: int = 17
    tRP: int = 17
    tRAS: int = 39
    tWR: int = 18
    tBL: int = 4            # burst 8, DDR
    tRTP: int = 9
    tRFC: int = 420         # 350 ns
    tREFI: int = 9360       # 7.8 us
    tRC_CLONE: int = 90     # ACT->PRE->ACT RowClone FPM sequence (~75 ns)

    def as_array(self):
        return jnp.array([self.tRCD, self.tRCD_reduced, self.tCL, self.tRP,
                          self.tRAS, self.tWR, self.tBL, self.tRTP,
                          self.tRFC, self.tREFI, self.tRC_CLONE], jnp.int32)


@dataclasses.dataclass(frozen=True)
class Geometry:
    n_banks: int = 16       # 4 bankgroups x 4 banks
    n_rows: int = 32768     # per bank (paper cfg: 32K rows)
    row_bytes: int = 8192   # 8 KiB row
    line_bytes: int = 64
    subarray_rows: int = 512

    @property
    def lines_per_row(self) -> int:
        return self.row_bytes // self.line_bytes


# request kinds in traces
READ, WRITE, RC_COPY, RC_INIT, NOP = 0, 1, 2, 3, 4


def neighbor_refresh_ticks(t: Timing) -> int:
    """Cost of one targeted neighbor-row refresh (the RowHammer
    mitigation primitive): an extra ACT+PRE row cycle on the bank.
    PARA/TRR policies charge this per fired mitigation."""
    return t.tRAS + t.tRP


def init_bank_state(geo: Geometry):
    return {
        "open_row": jnp.full((geo.n_banks,), -1, jnp.int32),
        "ready": jnp.zeros((geo.n_banks,), jnp.int32),     # tick when bank usable
        "act_at": jnp.zeros((geo.n_banks,), jnp.int32),    # last ACT tick (tRAS)
        "bus_busy": jnp.zeros((), jnp.int32),              # channel data bus
        "refs_done": jnp.zeros((), jnp.int32),
    }


def service_request(bank_state, t: Timing, kind, bank, row, now, trcd_eff):
    """Serve one request starting no earlier than tick ``now``.

    Banks pipeline: a request occupies its *bank* for the row-cycle work
    and the shared channel *bus* for tBL around the data burst, so
    streaming traffic across banks reaches burst-rate bandwidth — the
    behavior that separates a real memory system from a serialized one.

    trcd_eff: tRCD ticks to use for the activate (technique hook).
    Returns (new_bank_state, t_done, row_hit). Pure function of arrays.
    """
    open_row = bank_state["open_row"][bank]
    ready = bank_state["ready"][bank]
    act_at = bank_state["act_at"][bank]

    # refresh: catch up on REF debt before serving (simplified all-bank REF)
    refs_due = now // t.tREFI - bank_state["refs_done"]
    refs_due = jnp.maximum(refs_due, 0)
    ref_pen = refs_due * t.tRFC

    start = jnp.maximum(now, ready) + ref_pen
    is_hit = (open_row == row) & (kind != RC_COPY) & (kind != RC_INIT)
    is_closed = open_row < 0

    # PRE (row conflict) must respect tRAS from last ACT
    pre_at = jnp.maximum(start, act_at + t.tRAS)
    t_after_pre = pre_at + t.tRP
    act_start = jnp.where(is_closed, start, t_after_pre)

    # column access: CAS may issue once the row is open; data needs the bus
    t_act_done = act_start + trcd_eff
    col_start = jnp.where(is_hit, start, t_act_done)
    data_start = jnp.maximum(col_start + t.tCL, bank_state["bus_busy"])
    data_done = data_start + t.tBL

    # RowClone: ACT(src)-PRE-ACT(dst) fused sequence, no bus traffic
    rc_done = act_start + t.tRC_CLONE

    is_rc = (kind == RC_COPY) | (kind == RC_INIT)
    t_done = jnp.where(is_rc, rc_done, data_done)

    # bank stays busy past the burst for writes (tWR write recovery)
    bank_next = jnp.where(is_rc, rc_done,
                          jnp.where(kind == WRITE, data_done + t.tWR,
                                    data_done))
    new_act_at = jnp.where(is_hit, act_at, act_start)

    bs = dict(bank_state)
    bs["open_row"] = bank_state["open_row"].at[bank].set(row)
    bs["ready"] = bank_state["ready"].at[bank].set(bank_next)
    bs["act_at"] = bank_state["act_at"].at[bank].set(new_act_at)
    bs["bus_busy"] = jnp.where(is_rc, bank_state["bus_busy"], data_done)
    bs["refs_done"] = bank_state["refs_done"] + refs_due
    return bs, t_done, is_hit
