"""Logical-axis -> mesh-axis rules with divisibility-aware fallbacks.

The resolver is the single place where "how does this arch shard on this
mesh" is decided. Models annotate params/activations with *logical* names
("batch", "heads", "ffn", ...); launchers build a :class:`Rules` for the
(arch, mesh) pair; every annotation goes through :meth:`Rules.resolve`,
which falls back to replication when the dim is not divisible by the mesh
axis. The chosen layout is recorded so dry-run artifacts can report it.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# preference order per logical axis: first divisible candidate wins
DEFAULT_PREFS: Dict[str, Tuple[Axis, ...]] = {
    "batch":    (("pod", "data"), ("data",)),
    "seq":      (None,),                 # sequence replicated by default (SP opts in)
    "seq_res":  (("model",), None),      # residual-stream sequence parallelism (SP)
    "seq_sp":   (("data",), None),       # long-context KV/sequence sharding
    "hidden":   (None,),                 # residual stream replicated across model
    "hidden_tp": (("model",), None),     # TP'd hidden (qkv/ffn matmul output rows)
    "heads":    (("model",), None),
    "kv_heads": (("model",), None),
    # head_dim shards on model ONLY when the matching heads axis could not
    # (e.g. llava's 56 heads or GQA kv=2 on a 16-way axis) — see resolve()
    "head_dim": (None,),
    "kv_head_dim": (None,),
    "ffn":      (("model",), None),
    "vocab":    (("model",), None),
    "experts":  (("model",), None),
    "d_state":  (None,),
    "layers":   (None,),
}


class Rules:
    def __init__(self, mesh: Mesh, prefs: Optional[Dict[str, Tuple[Axis, ...]]] = None):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.prefs = dict(DEFAULT_PREFS)
        if prefs:
            self.prefs.update(prefs)
        self.decisions: Dict[Tuple[str, int], Axis] = {}

    def _axis_size(self, ax: Axis) -> int:
        if ax is None:
            return 1
        if isinstance(ax, str):
            ax = (ax,)
        n = 1
        for a in ax:
            n *= self.axis_sizes.get(a, 1)
        return n

    def _present(self, ax: Axis) -> Axis:
        """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in self.axis_sizes else None
        kept = tuple(a for a in ax if a in self.axis_sizes)
        return kept if kept else None

    def _heads_failed(self, kind: str) -> bool:
        dec = [v for (k, _), v in self.decisions.items() if k == kind]
        return bool(dec) and all(v is None for v in dec)

    def resolve(self, logical: Optional[str], size: int) -> Axis:
        if logical is None:
            return None
        prefs = self.prefs.get(logical, (None,))
        if logical == "head_dim" and self._heads_failed("heads"):
            prefs = (("model",), None)
        if logical == "kv_head_dim" and self._heads_failed("kv_heads"):
            prefs = (("model",), None)
        for cand in prefs:
            cand = self._present(cand)
            n = self._axis_size(cand)
            if n == 1 and cand is not None:
                cand = None
            if size % max(n, 1) == 0:
                self.decisions[(logical, size)] = cand
                return cand
        self.decisions[(logical, size)] = None
        return None

    def spec(self, *logical_and_size) -> P:
        """rules.spec(('batch', b), ('seq', s), ('hidden', d)) -> PartitionSpec."""
        axes = [self.resolve(n, s) for (n, s) in logical_and_size]
        used = set()
        out = []
        for ax in axes:
            flat = ax if isinstance(ax, tuple) else (ax,) if ax else ()
            if any(a in used for a in flat):
                ax = None
            used.update(flat)
            out.append(ax)
        return P(*out)

    def layout_report(self) -> Dict[str, str]:
        return {f"{k[0]}[{k[1]}]": str(v) for k, v in sorted(self.decisions.items())}


# ---- thread-local active rules so model code can annotate activations ----
_tls = threading.local()


def set_rules(rules: Optional[Rules]):
    _tls.rules = rules


def get_rules() -> Optional[Rules]:
    return getattr(_tls, "rules", None)


def shard(x, *logical):
    """Constrain activation x to the active rules (no-op outside a mesh)."""
    r = get_rules()
    if r is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = r.spec(*[(n, s) for n, s in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
