"""Standalone sweep-server process.

    PYTHONPATH=src python -m repro.service --port 7421 \
        --checkpoint artifacts/sweep_ckpt --persistent-cache

One process owns the warm engine (compile cache + executor pool); any
number of :class:`repro.service.SweepClient` processes attach over the
socket. Also reachable as ``python -m repro.launch.serve sweep ...``.
Ctrl-C drains in-flight work and exits; a second Ctrl-C aborts fast
(queued points fail typed, and with ``--checkpoint`` a pending
manifest is written for :func:`repro.service.load_pending`).
"""
from __future__ import annotations

import argparse
import time

from repro.service import ServiceConfig, SweepServer


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Persistent multi-client sweep server (shared warm "
                    "emulator engine with cross-client coalescing)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (printed on start)")
    ap.add_argument("--max-batch", type=int, default=128,
                    help="points per coalesced dispatch")
    ap.add_argument("--coalesce-window-ms", type=float, default=4.0,
                    help="max wait for cross-client merges")
    ap.add_argument("--max-pending", type=int, default=256,
                    help="per-client outstanding-point bound")
    ap.add_argument("--max-queue", type=int, default=2048,
                    help="global outstanding-point bound")
    ap.add_argument("--checkpoint", default=None,
                    help="group-checkpoint directory (resumable sweeps)")
    ap.add_argument("--persistent-cache", action="store_true",
                    help="enable the on-disk XLA compile cache")
    ap.add_argument("--stats-every", type=float, default=0.0, metavar="S",
                    help="print a stats line every S seconds")
    args = ap.parse_args(argv)

    cfg = ServiceConfig(
        max_batch=args.max_batch,
        coalesce_window_s=args.coalesce_window_ms / 1e3,
        max_pending=args.max_pending,
        max_queue=args.max_queue,
        checkpoint=args.checkpoint,
        persistent_cache=args.persistent_cache,
    )
    srv = SweepServer(cfg)
    host, port = srv.listen(args.host, args.port)
    print(f"sweep service listening on {host}:{port}", flush=True)
    try:
        while True:
            time.sleep(args.stats_every or 3600.0)
            if args.stats_every:
                s = srv.stats()
                d = s["dispatches"]
                print(f"dispatches={d['count']} points={d['points']} "
                      f"coalesce_ratio={s['coalesce_ratio']:.2f} "
                      f"rejected={s['rejected']} "
                      f"p50={s['latency_ms']['p50']}ms", flush=True)
    except KeyboardInterrupt:
        print("draining in-flight dispatches (Ctrl-C again to abort)...",
              flush=True)
        try:
            srv.close(drain=True)
        except KeyboardInterrupt:
            srv.close(drain=False)
    finally:
        srv.close(drain=False)


if __name__ == "__main__":
    main()
