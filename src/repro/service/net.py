"""Socket transport for the sweep service.

Wire format: 4-byte big-endian length prefix + a pickled Python object
per frame, in both directions. Requests are dicts ``{"op": ..., ...}``;
responses are ``{"ok": payload}`` or ``{"err": exception}`` — the
exception instance itself rides the frame and is re-raised client-side
(the service's typed errors implement ``__reduce__`` for this). Pickle
over a socket executes arbitrary code on load: this transport is for
TRUSTED networks only, and the default bind is loopback.

Ops (all handled by :func:`_handle`, one thread per connection):

* ``hello {name, weight}`` -> registered client name
* ``submit {client, points}`` -> list of ticket ids (atomic admission,
  so a :class:`~repro.service.server.QueueFullError` rejects the whole
  frame)
* ``wait {ids, timeout}`` -> ``{id: ("result", record) | ("error", exc)
  | ("pending", None)}``; resolved tickets are retired, pending ones
  stay claimable
* ``stats {}`` -> the server's stats snapshot
"""
from __future__ import annotations

import itertools
import pickle
import socket
import struct
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Any, Optional

__all__ = ["send_msg", "recv_msg", "serve"]

_HEADER = struct.Struct(">I")
MAX_FRAME = 1 << 30  # sanity bound; a frame this large is a protocol bug


def send_msg(sock: socket.socket, obj: Any) -> None:
    """Write one length-prefixed pickle frame."""
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_HEADER.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None if not buf else buf  # clean EOF only between frames
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket) -> Optional[Any]:
    """Read one frame; None on clean EOF (peer closed between frames)."""
    header = _recv_exact(sock, _HEADER.size)
    if header is None:
        return None
    if len(header) < _HEADER.size:
        raise ConnectionError("truncated frame header")
    (n,) = _HEADER.unpack(header)
    if n > MAX_FRAME:
        raise ConnectionError(f"oversized frame ({n} bytes)")
    data = _recv_exact(sock, n)
    if data is None or len(data) < n:
        raise ConnectionError("truncated frame body")
    return pickle.loads(data)


def _picklable(err: BaseException) -> BaseException:
    """Some executor-surfaced errors (e.g. XLA runtime exceptions)
    refuse to pickle; degrade those to a RuntimeError carrying the
    original type name and message rather than killing the connection."""
    try:
        pickle.loads(pickle.dumps(err))
        return err
    except Exception:
        return RuntimeError(f"{type(err).__name__}: {err}")


def _handle(server, conn: socket.socket) -> None:
    tickets: dict = {}
    ids = itertools.count(1)
    with conn:
        while True:
            try:
                msg = recv_msg(conn)
            except (ConnectionError, EOFError, OSError, pickle.PickleError):
                break
            if msg is None:
                break
            try:
                op = msg.get("op")
                if op == "hello":
                    resp = {"ok": server.register(msg.get("name"),
                                                  msg.get("weight", 1.0))}
                elif op == "submit":
                    futs = server.submit_points(msg["client"], msg["points"])
                    tids = [next(ids) for _ in futs]
                    tickets.update(zip(tids, futs))
                    resp = {"ok": tids}
                elif op == "wait":
                    out = {}
                    for tid in msg["ids"]:
                        fut = tickets.get(tid)
                        if fut is None:
                            out[tid] = ("error", KeyError(tid))
                            continue
                        try:
                            rec = fut.result(msg.get("timeout"))
                            out[tid] = ("result", rec)
                        except FutureTimeout:
                            out[tid] = ("pending", None)
                            continue
                        except BaseException as e:
                            out[tid] = ("error", _picklable(e))
                        tickets.pop(tid, None)
                    resp = {"ok": out}
                elif op == "stats":
                    resp = {"ok": server.stats()}
                elif op == "ping":
                    resp = {"ok": "pong"}
                else:
                    resp = {"err": ValueError(f"unknown op {op!r}")}
            except BaseException as e:
                resp = {"err": _picklable(e)}
            try:
                send_msg(conn, resp)
            except OSError:
                break


class _Listener:
    """Accept loop for one :class:`SweepServer`; one daemon thread per
    connection. ``close()`` stops accepting — established connections
    finish their current frame and then fail on the closed server."""

    def __init__(self, server, host: str, port: int):
        self._server = server
        self._sock = socket.create_server((host, port))
        self.address = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self._thread = threading.Thread(target=self._accept_loop,
                                        name="repro-sweep-accept",
                                        daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break
            threading.Thread(target=_handle, args=(self._server, conn),
                             name="repro-sweep-conn", daemon=True).start()

    def close(self) -> None:
        self._closed.set()
        try:
            self._sock.close()
        except OSError:
            pass


def serve(server, host: str = "127.0.0.1", port: int = 0) -> _Listener:
    """Bind and start accepting clients for ``server``; returns the
    listener (its ``.address`` is the bound ``(host, port)``)."""
    return _Listener(server, host, port)
