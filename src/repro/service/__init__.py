"""Sweep service: a persistent multi-client campaign server.

One warm emulator engine (in-memory executable LRU + optional
persistent XLA cache) serves many concurrent sweep clients. Submitted
grid points are bucketed by their campaign ``group_key``; compatible
points FROM DIFFERENT CLIENTS coalesce into shared batched dispatches
on the overlapped executor, and results demultiplex back to per-client
futures bit-identically to a direct ``Campaign.run`` of the same
points. Admission is bounded (queue-full is a typed
:class:`QueueFullError`, never a hang), scheduling between tenants is
weighted-fair (stride order over client virtual time), and shutdown
drains in-flight dispatches and leaves PR 8-style content-addressed
checkpoints so an interrupted sweep resumes with zero recomputation.

In-process::

    from repro.service import SweepServer, SweepClient

    with SweepServer() as srv:
        cli = SweepClient(server=srv, name="alice")
        cli.submit(trace, JETSON_NANO, mode="ts", workload="mm")
        records = cli.collect()        # == Campaign.run of the same points

Over a socket (one process owns the warm engine, many attach)::

    PYTHONPATH=src python -m repro.service --port 7421
    ...
    cli = SweepClient(address=("127.0.0.1", 7421), name="bob")

See ``examples/sweep_service.py`` and ``benchmarks --section service``.
"""
from repro.service.server import (QueueFullError, ServerClosedError,
                                  ServiceConfig, SweepServer, load_pending)
from repro.service.client import SweepClient

__all__ = ["SweepServer", "SweepClient", "ServiceConfig",
           "QueueFullError", "ServerClosedError", "load_pending"]
