"""SweepServer: admission, cross-client coalescing, fairness,
backpressure, and graceful drain around one warm emulator engine.

Architecture (one server == one process-wide warm engine):

* **Admission** — ``submit*`` appends to the calling client's bounded
  queue under the server lock. Bounds are enforced atomically per call
  (per-client ``max_pending`` outstanding points, global ``max_queue``);
  an over-bound submission raises :class:`QueueFullError` immediately —
  backpressure is a typed error, never a hang — and a closed server
  raises :class:`ServerClosedError`.
* **Fairness** — the dispatcher moves queued points into coalescing
  buckets in weighted stride order: each client carries a virtual time
  advanced by ``1/weight`` per admitted point, the lowest virtual time
  goes first, and an idle client re-entering catches up to the active
  minimum (it must not burn saved credit starving others). Under
  contention (full buckets slicing at ``max_batch``, bounded in-flight
  dispatches) a weight-2 client therefore lands ~2x the points per
  dispatch slice of a weight-1 client, and no client starves.
* **Coalescing** — buckets key on the campaign ``group_key`` (length
  bucket, SystemConfig — policy + faults ride it — mode, bloom shape),
  so points from DIFFERENT clients that a ``Campaign`` would batch
  together share one dispatch here too. A bucket flushes when it
  reaches ``max_batch`` or its oldest point has waited
  ``coalesce_window_s`` (the window is what lets a second client's
  burst join the first's dispatch; both the single- and multi-client
  paths pay it). Flushed buckets become executor tasks via the same
  ``emulator.prepare_tasks`` path ``Campaign.run`` uses, so results are
  bit-identical to a direct campaign over the same points — slot
  budgets and batch padding differ by composition, which the engine's
  ``run == run_many`` contract already guarantees is result-invariant.
* **Demux** — each dispatch's finalize writes disjoint ``outs`` slots;
  completion resolves per-point futures with ``{**out, **meta}``
  records, exactly ``Campaign.run``'s merge.
* **Checkpoints** — with ``checkpoint=dir``, every completed dispatch
  persists its group results through the PR 8 content-addressed path
  (``group-<digest>.pkl`` via ``campaign._group_digest``), and a
  dispatch whose digest already exists on disk is served from it with
  ZERO recomputation. On a non-draining close the still-queued points
  are written as a ``pending-*.pkl`` manifest (:func:`load_pending`),
  so an interrupted multi-client sweep resumes: finished groups load,
  unfinished groups recompute.
* **Shutdown** — ``close(drain=True)`` (default) stops admission,
  flushes every bucket, and waits for in-flight dispatches;
  ``drain=False`` fails queued points fast with
  :class:`ServerClosedError` (after writing the pending manifest) but
  still waits for in-flight dispatches — XLA executions cannot be
  interrupted, only awaited. Live servers are closed non-draining from
  an ``atexit`` hook, before the executor pool poisons itself, so a
  killed client process never leaves dispatch threads holding devices.
"""
from __future__ import annotations

import atexit
import collections
import dataclasses
import hashlib
import os
import pickle
import threading
import time
import weakref
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence

from repro.core import campaign as _campaign
from repro.core import emulator, executor
from repro.core.campaign import Point
from repro.core.emulator import Trace
from repro.core.timescale import SystemConfig

__all__ = ["QueueFullError", "ServerClosedError", "ServiceConfig",
           "SweepServer", "load_pending"]


class QueueFullError(RuntimeError):
    """Typed backpressure: the submission would exceed the client's
    ``max_pending`` or the server's ``max_queue`` outstanding-point
    bound. Carries enough to back off intelligently."""

    def __init__(self, client: str, requested: int, outstanding: int,
                 bound: int, scope: str):
        self.client, self.requested = client, requested
        self.outstanding, self.bound, self.scope = outstanding, bound, scope
        super().__init__(
            f"sweep-service {scope} queue full for client {client!r}: "
            f"{outstanding} outstanding + {requested} requested > "
            f"{bound} bound; drain results (collect) or raise the bound")

    def __reduce__(self):  # keep the typed fields across the socket
        return (QueueFullError, (self.client, self.requested,
                                 self.outstanding, self.bound, self.scope))


class ServerClosedError(RuntimeError):
    """The server is closed (or closing): no new submissions, and on a
    non-draining close, queued-but-undispatched points fail with this.
    ``checkpoint`` names the pending-manifest directory when one was
    written (resume via :func:`load_pending`)."""

    def __init__(self, msg: str, checkpoint: Optional[str] = None):
        self.checkpoint = checkpoint
        self._msg = msg
        super().__init__(msg + (f" (pending manifest in {checkpoint})"
                                if checkpoint else ""))

    def __reduce__(self):  # keep the typed fields across the socket
        return (ServerClosedError, (self._msg, self.checkpoint))


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Server knobs; defaults suit a single-host shared engine."""
    max_batch: int = 128            # points per coalesced dispatch
    coalesce_window_s: float = 0.004  # max wait for cross-client merges
    max_pending: int = 256          # per-client outstanding bound
    max_queue: int = 2048           # global outstanding bound
    max_inflight: Optional[int] = None  # concurrent dispatches (None ->
    #                                     executor.workers())
    checkpoint: Optional[str] = None    # PR 8 group-checkpoint dir
    persistent_cache: bool = False      # wire artifacts/xla_cache on init

    def __post_init__(self):
        for name in ("max_batch", "max_pending", "max_queue"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        if self.coalesce_window_s < 0:
            raise ValueError(f"coalesce_window_s must be >= 0, "
                             f"got {self.coalesce_window_s}")


@dataclasses.dataclass
class _Job:
    point: Point
    future: Future
    client: str
    t_submit: float


@dataclasses.dataclass
class _Client:
    name: str
    weight: float
    vtime: float = 0.0
    queue: "collections.deque[_Job]" = dataclasses.field(
        default_factory=collections.deque)
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    outstanding: int = 0


@dataclasses.dataclass
class _Bucket:
    jobs: List[_Job]
    t_open: float


@dataclasses.dataclass
class _Dispatch:
    key: tuple
    jobs: List[_Job]
    outs: List[Optional[dict]]
    t_start: float
    n_tasks: int = 0
    n_done: int = 0
    failure: Optional[executor.TaskFailure] = None
    loaded: bool = False


def _group_label(key: tuple) -> str:
    """Stable short display label for one group key (stats dicts need
    hashable, JSON-friendly keys; the tuple itself embeds arrays via
    SystemConfig policy tables only by digest, so repr is stable)."""
    return hashlib.sha1(repr(key).encode()).hexdigest()[:10]


def load_pending(directory: str) -> List[Point]:
    """Load every ``pending-*.pkl`` manifest a non-draining
    :meth:`SweepServer.close` left in ``directory`` and return the
    still-unexecuted :class:`Point` objects (submission order within
    each manifest). Feed them back through a ``Campaign`` (or a fresh
    server) with ``checkpoint=directory`` and the finished groups load
    from their PR 8 checkpoints while these recompute."""
    pts: List[Point] = []
    for name in sorted(os.listdir(directory)):
        if name.startswith("pending-") and name.endswith(".pkl"):
            with open(os.path.join(directory, name), "rb") as fh:
                pts.extend(pickle.load(fh))
    return pts


_LIVE_SERVERS: "weakref.WeakSet[SweepServer]" = weakref.WeakSet()


def _close_live_servers() -> None:  # pragma: no cover - exercised via
    # subprocess in tests/test_service.py (atexit ordering: this runs
    # before executor.shutdown poisons the pool, so in-flight dispatches
    # drain instead of deadlocking interpreter teardown)
    for srv in list(_LIVE_SERVERS):
        try:
            srv.close(drain=False, timeout=10.0)
        except Exception:
            pass


atexit.register(_close_live_servers)


class SweepServer:
    """A long-lived multi-client campaign server over one warm engine.

    See the module docstring for the architecture. The in-process API
    (used directly by :class:`repro.service.client.SweepClient` and by
    the socket layer in :mod:`repro.service.net`):

    * :meth:`register` a client (name + fairness weight),
    * :meth:`submit` / :meth:`submit_many` points (returns
      :class:`concurrent.futures.Future` per point, resolving to the
      same record dict ``Campaign.run`` would produce),
    * :meth:`stats` for queue depths, coalesce ratios, compile
      hit/miss deltas, and dispatch latency percentiles,
    * :meth:`listen` to accept socket clients,
    * :meth:`close` to drain and shut down (also a context manager).
    """

    def __init__(self, config: Optional[ServiceConfig] = None, **overrides):
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        if config.persistent_cache:
            from repro.utils import jax_compat
            jax_compat.enable_persistent_compile_cache()
        if config.checkpoint:
            os.makedirs(config.checkpoint, exist_ok=True)

        self._cond = threading.Condition()
        self._clients: Dict[str, _Client] = {}
        self._buckets: "collections.OrderedDict[tuple, _Bucket]" = \
            collections.OrderedDict()
        self._inflight: Dict[int, _Dispatch] = {}
        self._closed = False
        self._drain = True
        self._stopped = threading.Event()
        self._listener = None          # net._Listener when listen()ing
        self._anon = 0

        # stats (under self._cond's lock)
        self._n_dispatches = 0
        self._n_loaded = 0
        self._n_points_dispatched = 0
        self._n_client_slots = 0       # sum over dispatches of distinct clients
        self._n_policy_slots = 0       # runtime-policy-axis points dispatched
        self._groups: Dict[str, Dict[str, int]] = {}
        self._latencies: "collections.deque[float]" = \
            collections.deque(maxlen=4096)
        cs = emulator.cache_stats()
        self._compile_base = {"hits": cs["hits"], "misses": cs["misses"]}

        self._dispatcher = threading.Thread(
            target=self._loop, name="repro-sweep-dispatch", daemon=True)
        self._dispatcher.start()
        _LIVE_SERVERS.add(self)

    # ------------------------------------------------------------- admission

    def register(self, name: Optional[str] = None,
                 weight: float = 1.0) -> str:
        """Register (or re-register) a client; returns its name. Weight
        sets the fair-share ratio (2.0 == twice the dispatch share of a
        1.0 client under contention). Re-registering adjusts the
        weight and keeps counters."""
        if weight <= 0:
            raise ValueError(f"client weight must be > 0, got {weight}")
        with self._cond:
            if self._closed:
                raise ServerClosedError("server is closed")
            if name is None:
                self._anon += 1
                name = f"client-{self._anon}"
            c = self._clients.get(name)
            if c is None:
                self._clients[name] = _Client(name=name, weight=float(weight))
            else:
                c.weight = float(weight)
            return name

    def _client(self, name: str) -> _Client:
        c = self._clients.get(name)
        if c is None:
            raise ValueError(f"unknown client {name!r}; register() first")
        return c

    def submit(self, client: str, trace: Trace, sys: SystemConfig,
               mode: str = "ts", bloom: Optional[tuple] = None,
               **meta) -> Future:
        """Submit one grid point for ``client``; returns a Future that
        resolves to the record ``Campaign.run`` would produce for the
        same point (emulator outputs merged with ``meta``). Raises
        :class:`QueueFullError` / :class:`ServerClosedError`; typed
        ``ValueError`` for invalid points (same checks as
        ``Campaign.add``)."""
        emulator.check_mode(mode)
        if not isinstance(trace, Trace):
            raise ValueError(
                f"sweep-service points need a Trace, got "
                f"{type(trace).__name__} (stream points are unsupported "
                f"over the service; drive emulator.run_stream directly)")
        return self.submit_points(client, [Point(trace, sys, mode, bloom,
                                                 meta)])[0]

    def submit_points(self, client: str,
                      points: Sequence[Point]) -> List[Future]:
        """Atomic multi-point admission: either every point is admitted
        (in order) or none is and :class:`QueueFullError` carries which
        bound would overflow. Stream points are rejected (typed
        ValueError) — their inputs are one-shot iterators that cannot
        be coalesced or checkpointed."""
        points = list(points)
        for p in points:
            if p.stream:
                raise ValueError(
                    "stream points are unsupported over the sweep service; "
                    "use Campaign(stream=True) or emulator.run_stream")
        with self._cond:
            if self._closed:
                raise ServerClosedError("server is closed")
            c = self._client(client)
            if c.outstanding + len(points) > self.config.max_pending:
                c.rejected += len(points)
                raise QueueFullError(client, len(points), c.outstanding,
                                     self.config.max_pending, "per-client")
            total = sum(cl.outstanding for cl in self._clients.values())
            if total + len(points) > self.config.max_queue:
                c.rejected += len(points)
                raise QueueFullError(client, len(points), total,
                                     self.config.max_queue, "global")
            if c.outstanding == 0 and self._clients:
                # idle client re-entering: catch its virtual time up to
                # the active minimum so banked idle credit cannot starve
                # currently-active clients
                active = [cl.vtime for cl in self._clients.values()
                          if cl.outstanding > 0]
                if active:
                    c.vtime = max(c.vtime, min(active))
            now = time.monotonic()
            futs = []
            for p in points:
                job = _Job(point=p, future=Future(), client=client,
                           t_submit=now)
                c.queue.append(job)
                futs.append(job.future)
            c.submitted += len(points)
            c.outstanding += len(points)
            self._cond.notify_all()
            return futs

    # ------------------------------------------------------------ dispatcher

    def _drain_queues_locked(self) -> None:
        """Move queued jobs into coalescing buckets in weighted stride
        order (lowest client virtual time first, +1/weight per point).
        Order within a bucket is the fair order, so when a bucket
        slices at ``max_batch`` under load, each slice carries clients
        in weight proportion."""
        now = time.monotonic()
        while True:
            eligible = [c for c in self._clients.values() if c.queue]
            if not eligible:
                return
            c = min(eligible, key=lambda cl: (cl.vtime, cl.name))
            job = c.queue.popleft()
            c.vtime += 1.0 / c.weight
            key = job.point.group_key()
            b = self._buckets.get(key)
            if b is None:
                self._buckets[key] = _Bucket(jobs=[job], t_open=now)
            else:
                b.jobs.append(job)

    def _take_flushes_locked(self, force: bool):
        """Pop bucket slices ready to dispatch, respecting the
        in-flight cap. Returns (flushes, seconds-until-next-deadline)."""
        cap = self.config.max_inflight or max(1, executor.workers())
        now = time.monotonic()
        flushes, next_dl = [], None
        for key in list(self._buckets):
            if len(self._inflight) + len(flushes) >= cap:
                next_dl = 0.05  # re-check soon; a demux will notify anyway
                break
            b = self._buckets[key]
            ripe = force or len(b.jobs) >= self.config.max_batch \
                or (now - b.t_open) >= self.config.coalesce_window_s
            if not ripe:
                dl = b.t_open + self.config.coalesce_window_s - now
                next_dl = dl if next_dl is None else min(next_dl, dl)
                continue
            slice_, rest = (b.jobs[:self.config.max_batch],
                            b.jobs[self.config.max_batch:])
            if rest:
                b.jobs = rest   # keeps t_open: the rest has waited too
                next_dl = 0.0 if next_dl is None else min(next_dl, 0.0)
            else:
                del self._buckets[key]
            flushes.append((key, slice_))
        return flushes, next_dl

    def _loop(self) -> None:
        while True:
            with self._cond:
                if self._closed and not self._drain:
                    break   # abort mode: queued work fails, never runs
                self._drain_queues_locked()
                closing = self._closed
                force = closing  # drain mode: flush regardless of window
                flushes, next_dl = self._take_flushes_locked(force)
                if not flushes:
                    if closing and not self._buckets and not self._inflight \
                            and not any(c.queue
                                        for c in self._clients.values()):
                        break
                    timeout = 0.5 if next_dl is None \
                        else min(max(next_dl, 0.0) + 1e-4, 0.5)
                    self._cond.wait(timeout)
                    continue
            for key, jobs in flushes:
                self._dispatch(key, jobs)
        if not self._drain:
            self._abort_pending()
        self._await_inflight()
        self._stopped.set()

    def _dispatch(self, key: tuple, jobs: List[_Job]) -> None:
        """Build and launch one coalesced dispatch (dispatcher thread:
        executable resolution/priming stays single-threaded here, the
        same determinism argument as ``Campaign.run``'s prepare phase).
        Any preparation failure fails exactly this dispatch's futures,
        never the server."""
        pts = [j.point for j in jobs]
        p0 = pts[0]
        disp = _Dispatch(key=key, jobs=jobs, outs=[None] * len(pts),
                         t_start=time.monotonic())
        try:
            ckpt_path = None
            if self.config.checkpoint:
                ckpt_path = os.path.join(
                    self.config.checkpoint,
                    f"group-{_campaign._group_digest(key, pts)}.pkl")
                if os.path.exists(ckpt_path):
                    with open(ckpt_path, "rb") as fh:
                        outs = pickle.load(fh)
                    if len(outs) == len(pts) and all(
                            o is not None for o in outs):
                        disp.outs = outs
                        disp.loaded = True
                        self._finish(disp)
                        return
            blooms = None
            if p0.bloom is not None:
                same = all(p.bloom is p0.bloom for p in pts)
                blooms = p0.bloom if same else [p.bloom for p in pts]
            # runtime policy axis: policy points group apart from
            # staged/legacy ones (their group_key carries a policy
            # shape element), so a whole dispatch rides the axis
            pkw = {} if p0.policy is None else dict(
                policies=[p.policy for p in pts],
                policy_costs=[p.policy_cost for p in pts])
            tasks = emulator.prepare_tasks(
                [p.trace for p in pts], p0.sys, [p.mode for p in pts],
                blooms, disp.outs, **pkw)
            if ckpt_path is not None:
                for t in tasks:
                    t.finalize = _campaign._checkpointed(
                        t.finalize, disp.outs, ckpt_path)
            disp.n_tasks = len(tasks)
            with self._cond:
                self._inflight[id(disp)] = disp
            for t in tasks:
                executor.submit_task(t).add_done_callback(
                    lambda f, d=disp: self._task_done(d, f))
        except BaseException as e:
            with self._cond:
                self._inflight.pop(id(disp), None)
            self._fail_jobs(jobs, e)

    def _task_done(self, disp: _Dispatch, fut: Future) -> None:
        """Worker-thread callback: count the dispatch's tasks down and
        demux when the last settles."""
        try:
            failure = fut.result()
        except BaseException as e:   # submit machinery itself failed
            failure = executor.TaskFailure(None, "", e, 0)
        last = False
        with self._cond:
            disp.n_done += 1
            if failure is not None and disp.failure is None:
                disp.failure = failure
            last = disp.n_done >= disp.n_tasks
        if last:
            self._finish(disp)

    def _finish(self, disp: _Dispatch) -> None:
        """Demultiplex one settled dispatch back to per-client futures
        and fold its stats in. Record merge (``{**out, **meta}``, with
        the meta-clash ValueError) matches ``Campaign.run`` exactly."""
        now = time.monotonic()
        for job, out in zip(disp.jobs, disp.outs):
            if disp.failure is not None and out is None:
                job.future.set_exception(disp.failure.error)
            elif out is None:
                job.future.set_exception(RuntimeError(
                    f"dispatch {_group_label(disp.key)} finished without "
                    f"a result for client {job.client!r}"))
            else:
                clash = set(out) & set(job.point.meta)
                if clash:
                    job.future.set_exception(ValueError(
                        f"meta keys shadow emulator result fields: "
                        f"{sorted(clash)}"))
                else:
                    job.future.set_result({**out, **job.point.meta})
        with self._cond:
            self._inflight.pop(id(disp), None)
            self._n_dispatches += 1
            self._n_loaded += int(disp.loaded)
            self._n_points_dispatched += len(disp.jobs)
            names = {j.client for j in disp.jobs}
            self._n_client_slots += len(names)
            npol = sum(1 for j in disp.jobs if j.point.policy is not None)
            self._n_policy_slots += npol
            g = self._groups.setdefault(
                _group_label(disp.key),
                {"points": 0, "dispatches": 0, "policies": 0})
            g["points"] += len(disp.jobs)
            g["dispatches"] += 1
            g["policies"] += npol
            for job in disp.jobs:
                c = self._clients.get(job.client)
                if c is not None:
                    c.completed += 1
                    c.outstanding -= 1
                self._latencies.append(now - job.t_submit)
            self._cond.notify_all()

    def _fail_jobs(self, jobs: Sequence[_Job], err: BaseException) -> None:
        for job in jobs:
            job.future.set_exception(err)
        with self._cond:
            for job in jobs:
                c = self._clients.get(job.client)
                if c is not None:
                    c.outstanding -= 1
            self._cond.notify_all()

    # --------------------------------------------------------------- close

    def _abort_pending(self) -> None:
        """Non-draining close: persist still-queued points as a pending
        manifest (when checkpointing), then fail their futures fast."""
        with self._cond:
            jobs: List[_Job] = []
            for b in self._buckets.values():
                jobs.extend(b.jobs)
            self._buckets.clear()
            for c in self._clients.values():
                jobs.extend(c.queue)
                c.queue.clear()
        ckpt = self.config.checkpoint
        if jobs and ckpt:
            path = os.path.join(ckpt, f"pending-{os.getpid()}.pkl")
            tmp = f"{path}.tmp"
            with open(tmp, "wb") as fh:
                pickle.dump([j.point for j in jobs], fh,
                            protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        self._fail_jobs(jobs, ServerClosedError(
            f"server closed before dispatching {len(jobs)} queued "
            f"point(s)", checkpoint=ckpt if jobs else None))

    def _await_inflight(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._inflight:
                rem = None if deadline is None \
                    else max(deadline - time.monotonic(), 0.0)
                if rem == 0.0:
                    return
                self._cond.wait(0.1 if rem is None else min(rem, 0.1))

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Shut the server down. ``drain=True`` (default) dispatches
        everything admitted and waits for it; ``drain=False`` fails
        queued points fast (writing the pending manifest when
        checkpointing) but still awaits in-flight dispatches — a device
        execution can only be awaited, not interrupted. Idempotent;
        afterwards every ``submit`` raises :class:`ServerClosedError`."""
        with self._cond:
            already = self._closed
            self._closed = True
            self._drain = self._drain and drain
            self._cond.notify_all()
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        if not already or self._dispatcher.is_alive():
            self._dispatcher.join(timeout)
        self._stopped.wait(0 if timeout is None else timeout)
        _LIVE_SERVERS.discard(self)

    def __enter__(self) -> "SweepServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # --------------------------------------------------------------- stats

    def listen(self, host: str = "127.0.0.1", port: int = 0):
        """Start accepting socket clients; returns the bound
        ``(host, port)``. See :mod:`repro.service.net` for the protocol
        (length-prefixed pickle frames — trusted networks only; the
        default bind is loopback)."""
        from repro.service import net
        with self._cond:
            if self._closed:
                raise ServerClosedError("server is closed")
            if self._listener is not None:
                raise RuntimeError("server is already listening")
        self._listener = net.serve(self, host, port)
        return self._listener.address

    def stats(self) -> dict:
        """One consistent snapshot of service health: per-client and
        per-group counters, coalescing ratios (``coalesce_ratio`` is
        mean DISTINCT CLIENTS per dispatch — >1.0 means cross-client
        coalescing is really happening; ``points_per_dispatch`` is the
        batching ratio), compile hit/miss deltas since server start
        (the warm-engine claim), and dispatch latency percentiles
        (submit -> result, seconds->ms)."""
        with self._cond:
            lat = sorted(self._latencies)
            nd = self._n_dispatches

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                return lat[min(len(lat) - 1, int(p * (len(lat) - 1)))]

            out = {
                "clients": {
                    c.name: {"weight": c.weight, "submitted": c.submitted,
                             "completed": c.completed,
                             "rejected": c.rejected,
                             "queue_depth": c.outstanding}
                    for c in self._clients.values()},
                "groups": dict(self._groups),
                "dispatches": {
                    "count": nd, "loaded_from_checkpoint": self._n_loaded,
                    "points": self._n_points_dispatched,
                    "policy_points": self._n_policy_slots,
                    "inflight": len(self._inflight),
                    "bucketed": sum(len(b.jobs)
                                    for b in self._buckets.values()),
                },
                "points_per_dispatch": (self._n_points_dispatched / nd
                                        if nd else 0.0),
                "coalesce_ratio": (self._n_client_slots / nd if nd else 0.0),
                # runtime-policy-axis coalescing: mean policy-operand
                # points per dispatch (mirrors clients_per_dispatch; a
                # 256-policy one-dispatch sweep shows 256.0 here)
                "policies_per_dispatch": (self._n_policy_slots / nd
                                          if nd else 0.0),
                "rejected": sum(c.rejected for c in self._clients.values()),
                "latency_ms": {
                    "p50": round(pct(0.50) * 1e3, 3),
                    "p90": round(pct(0.90) * 1e3, 3),
                    "p99": round(pct(0.99) * 1e3, 3),
                    "n": len(lat),
                },
                "closed": self._closed,
            }
        cs = emulator.cache_stats()
        out["compile"] = {
            "hits": cs["hits"] - self._compile_base["hits"],
            "misses": cs["misses"] - self._compile_base["misses"],
            "cache": {k: cs[k] for k in
                      ("hits", "misses", "evictions", "size", "capacity",
                       "lookups")},
        }
        return out
