"""SweepClient: one tenant's handle on a :class:`SweepServer`.

Two transports behind one API:

* **In-process** (``SweepClient(server=srv)``) — calls straight into
  the server object; futures are the server's own.
* **Socket** (``SweepClient(address=(host, port))``) — speaks the
  length-prefixed pickle protocol of :mod:`repro.service.net` to a
  server in another process (``python -m repro.service``). Typed
  service errors (:class:`QueueFullError`, :class:`ServerClosedError`)
  are re-raised client-side with their fields intact.

The client tracks its submissions in order; :meth:`collect` returns
their records in that order — the exact list ``Campaign.run`` would
return for the same points — and clears the pending set.
"""
from __future__ import annotations

import socket
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Any, List, Optional, Sequence, Tuple

from repro.core.campaign import Point
from repro.core.emulator import Trace
from repro.core.timescale import SystemConfig

__all__ = ["SweepClient"]


class SweepClient:
    """One tenant of a sweep server (in-process or over a socket).

    Args:
        server: a live :class:`SweepServer` for in-process use.
        address: ``(host, port)`` of a listening server; mutually
            exclusive with ``server``.
        name: client name (server-assigned when None); shows up in
            ``stats()["clients"]``.
        weight: fair-share weight (2.0 == twice the dispatch share of a
            1.0 client under contention).
    """

    def __init__(self, server=None,
                 address: Optional[Tuple[str, int]] = None,
                 name: Optional[str] = None, weight: float = 1.0):
        if (server is None) == (address is None):
            raise ValueError("pass exactly one of server= or address=")
        self._server = server
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()
        self._pending: List[Any] = []   # Futures (in-process) or ticket ids
        if server is not None:
            self.name = server.register(name, weight)
        else:
            self._sock = socket.create_connection(address)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self.name = self._request({"op": "hello", "name": name,
                                       "weight": weight})

    # ----------------------------------------------------------- transport

    def _request(self, msg: dict) -> Any:
        from repro.service import net
        with self._lock:
            if self._sock is None:
                raise ConnectionError("client is closed")
            net.send_msg(self._sock, msg)
            resp = net.recv_msg(self._sock)
        if resp is None:
            raise ConnectionError("sweep server closed the connection")
        if "err" in resp:
            raise resp["err"]
        return resp["ok"]

    # ----------------------------------------------------------- submission

    def submit(self, trace: Trace, sys: SystemConfig, mode: str = "ts",
               bloom: Optional[tuple] = None, **meta) -> None:
        """Queue one grid point (meta keys ride into its record, as in
        ``Campaign.add``). Raises the service's typed errors
        immediately on backpressure or closure — nothing is buffered
        client-side."""
        self.submit_points([Point(trace, sys, mode, bloom, meta)])

    def submit_points(self, points: Sequence[Point]) -> int:
        """Atomically queue several points; returns how many are now
        pending. All-or-nothing: on :class:`QueueFullError` none of
        ``points`` was admitted."""
        points = list(points)
        if self._server is not None:
            futs = self._server.submit_points(self.name, points)
            with self._lock:
                self._pending.extend(futs)
        else:
            tids = self._request({"op": "submit", "client": self.name,
                                  "points": points})
            with self._lock:
                self._pending.extend(tids)
        return len(self._pending)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------------- results

    def collect(self, timeout: Optional[float] = None,
                return_errors: bool = False) -> List[dict]:
        """Block for every pending point and return their records in
        submission order (bit-identical to ``Campaign.run`` over the
        same points), clearing the pending set. A failed point raises
        its error — or, with ``return_errors=True``, appears in-place
        as the exception object. On ``timeout`` (seconds, whole-call)
        raises :class:`concurrent.futures.TimeoutError` and keeps the
        pending set intact."""
        with self._lock:
            handles = list(self._pending)
        if self._server is not None:
            out: List[Any] = []
            for fut in handles:
                try:
                    out.append(fut.result(timeout))
                except FutureTimeout:
                    raise
                except BaseException as e:
                    if not return_errors:
                        raise
                    out.append(e)
        else:
            got = self._request({"op": "wait", "ids": handles,
                                 "timeout": timeout})
            if any(got[t][0] == "pending" for t in handles):
                raise FutureTimeout(
                    f"{sum(1 for t in handles if got[t][0] == 'pending')} "
                    f"point(s) still pending after {timeout}s")
            out = []
            for tid in handles:
                kind, payload = got[tid]
                if kind == "error" and not return_errors:
                    raise payload
                out.append(payload)
        with self._lock:
            self._pending = self._pending[len(handles):]
        return out

    # --------------------------------------------------------------- misc

    def stats(self) -> dict:
        """The server's stats snapshot (see ``SweepServer.stats``)."""
        if self._server is not None:
            return self._server.stats()
        return self._request({"op": "stats"})

    def close(self) -> None:
        """Drop the connection (socket mode); pending results on the
        server are abandoned. In-process clients have nothing to close."""
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                finally:
                    self._sock = None

    def __enter__(self) -> "SweepClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
