"""Parse collective traffic out of (partitioned) HLO text.

``compiled.as_text()`` for a pjit'd program is the SPMD single-program
module, so shapes on collective ops are *per-device*. We sum operand
bytes per collective kind; the roofline collective term is then
per-device bytes / link bandwidth.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-gather.5 = bf16[16,4096,256]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^ ]*)\s+"
    r"(?P<kind>" + "|".join(COLLECTIVES) + r")(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {kind: {"bytes": per-device operand bytes, "count": n}}."""
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        # async pairs appear as -start/-done; count once (on start)
        if f"{kind}-done(" in line:
            continue
        # result bytes: sum every shape on the lhs (tuples for grouped ops)
        lhs = line.split(f" {kind}", 1)[0]
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(lhs))
        out[kind]["bytes"] += nbytes
        out[kind]["count"] += 1
    return out


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_bytes(hlo_text).values()))
