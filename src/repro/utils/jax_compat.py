"""Version-compat shims over the installed JAX.

The codebase targets the shard_map/cost_analysis API surface of recent
JAX, but must run on whatever the container ships (currently 0.4.37).
Every call site goes through these helpers instead of probing
``jax.<attr>`` itself, so a JAX upgrade changes exactly one file.

* :data:`shard_map` — ``jax.shard_map`` when present (>= 0.6), else
  ``jax.experimental.shard_map.shard_map``.
* :func:`pvary` — mark a value device-varying over mesh axes. Newer
  shard_map requires the annotation (``jax.lax.pvary`` /
  ``jax.lax.pcast``); older shard_map has no such notion, so the shim
  degrades to identity (pair with ``shard_map_kwargs`` below, which
  disables replication checking there).
* :func:`shard_map_kwargs` — extra kwargs for :data:`shard_map` on this
  JAX version (``check_rep=False`` on old JAX, where device-varying
  carries would otherwise fail the replication checker).
* :func:`cost_analysis_dict` — ``Compiled.cost_analysis()`` normalized
  to one flat dict. Depending on version it returns a dict, a list with
  one dict per partition, or None.
* :func:`enable_fast_cpu_scan` — select the XLA:CPU runtime that keeps
  the emulator's long scalar-carry scans fast (see docstring). Call it
  at process entry, before the first jax computation; calling it after
  the backend initialized raises (the flag would be silently ignored).
* :func:`enable_persistent_compile_cache` — wire up JAX's on-disk XLA
  compilation cache (default ``artifacts/xla_cache/``) so a fresh
  process re-running an already-seen sweep skips the cold compiles;
  :func:`persistent_cache_stats` counts its hits/misses via the JAX
  monitoring events (version-tolerant: counters stay zero if the event
  API moved).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    _NEW_SHARD_MAP = True
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401
    _NEW_SHARD_MAP = False


def shard_map_kwargs() -> Dict[str, Any]:
    """Extra kwargs to pass to :data:`shard_map` on this JAX version."""
    return {} if _NEW_SHARD_MAP else {"check_rep": False}


def pvary(x, axis_names):
    """Mark ``x`` device-varying over ``axis_names`` inside shard_map."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_names, to="varying")
    return x  # old shard_map: no varying-ness tracking (check_rep=False)


def enable_fast_cpu_scan() -> bool:
    """Select the XLA:CPU runtime that keeps long scalar-carry scans fast.

    The thunk runtime (jaxlib >= 0.4.32 default) executes each of the
    ~100 tiny ops in the emulator's scan body through its intra-op
    thread pool and defeats in-place dynamic-update-slice on the scan
    carry; for an 8k-slot emulation that is ~30 us of synchronization
    per slot — a 30-40x steady-state slowdown on the batched engine
    (measured in ``benchmarks/run.py --section sim_speed``). The legacy
    inline runtime has neither problem. Matmul-heavy model code is
    unaffected either way (both dispatch to Eigen).

    Also disables XLA:CPU *async dispatch* (where supported): async
    dispatch enqueues every execution onto one per-device execute
    thread, which silently serializes the overlapped campaign executor
    (``repro.core.executor``) — with it off, a warm executable runs
    synchronously on the calling worker thread, so independent compile
    groups genuinely execute in parallel across cores.

    Must run before the CPU backend is created: returns True when the
    flag is (now) in effect for future compilations, and raises
    ``RuntimeError`` when the backend already initialized without it —
    the flag would be silently ignored and every emulation scan would
    quietly run ~30x slower, so a late call is a programming error (fix
    the call order), not a condition to limp past. Returns False only
    when the operator explicitly pinned the thunk runtime on via
    ``XLA_FLAGS`` (their call; warn and respect it). Known caveat: the
    legacy runtime does not populate per-op ``cost_analysis()``
    metrics, so flops-accounting tools (``repro.launch.dryrun``)
    should not run under it.
    """
    try:  # sync dispatch: see docstring (anytime config, not an XLA flag)
        jax.config.update("jax_cpu_enable_async_dispatch", False)
    except (AttributeError, KeyError):  # pragma: no cover - option absent
        pass
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" in flags:
        if "xla_cpu_use_thunk_runtime=false" in flags:
            return True  # operator already pinned the fast runtime
        import warnings
        warnings.warn(
            "XLA_FLAGS pins xla_cpu_use_thunk_runtime on — emulation "
            "scans will run ~30x slower steady-state", stacklevel=2)
        return False
    try:
        from jax._src import xla_bridge
        backend_up = bool(xla_bridge._backends)
    except (ImportError, AttributeError):  # pragma: no cover - API moved
        backend_up = False
    if backend_up:  # flag would be silently ignored — refuse loudly
        raise RuntimeError(
            "enable_fast_cpu_scan() called after the JAX backend "
            "initialized (e.g. after importing repro.core.emulator or "
            "running any jax computation) — the XLA_FLAGS it sets would "
            "be ignored and emulation scans would run on the slow thunk "
            "runtime. Call it first thing at process entry, before any "
            "repro.core import.")
    os.environ["XLA_FLAGS"] = \
        (flags + " --xla_cpu_use_thunk_runtime=false").strip()
    return True


_PCACHE_STATS = {"hits": 0, "misses": 0}
_PCACHE_DIR: str | None = None


def _pcache_event(event: str, **kwargs) -> None:
    if event == "/jax/compilation_cache/cache_hits":
        _PCACHE_STATS["hits"] += 1
    elif event == "/jax/compilation_cache/cache_misses":
        _PCACHE_STATS["misses"] += 1


def enable_persistent_compile_cache(
        cache_dir: str = os.path.join("artifacts", "xla_cache")) -> str:
    """Persist XLA executables to ``cache_dir`` across processes.

    A second process running the same sweep (same shapes, configs, XLA
    flags) then loads each executable from disk instead of re-paying
    the cold compile — on the emulator scan that is seconds per
    compile-key group. Every entry-size / compile-time threshold is
    zeroed so the emulator's scan executables always qualify.

    Call it at process entry, next to :func:`enable_fast_cpu_scan`:
    JAX latches its cache-enabled decision at the first compilation, so
    the defensive ``reset_cache()`` below only reliably re-opens the
    decision on JAX versions that expose it. Safe to call repeatedly
    (e.g. to move the directory). Returns the absolute cache dir.
    """
    global _PCACHE_DIR
    cache_dir = os.path.abspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    if _PCACHE_DIR is None:  # register the hit/miss listener once
        try:
            from jax._src import monitoring
            monitoring.register_event_listener(_pcache_event)
        except (ImportError, AttributeError):  # pragma: no cover
            pass  # counters stay zero; caching itself still works
    try:  # re-open JAX's latched is-cache-used decision if already taken
        from jax._src import compilation_cache
        compilation_cache.reset_cache()
    except (ImportError, AttributeError):  # pragma: no cover
        pass
    _PCACHE_DIR = cache_dir
    return cache_dir


def persistent_cache_stats() -> Dict[str, Any]:
    """{'hits': n, 'misses': n, 'dir': path-or-None} for the on-disk
    XLA compilation cache (all-zero/None until
    :func:`enable_persistent_compile_cache` ran). A hit means an XLA
    compile was skipped by loading the executable from disk."""
    return {**_PCACHE_STATS, "dir": _PCACHE_DIR}


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as one flat {metric: value} dict.

    Newer JAX returns a single dict; 0.4.x returns a list with one dict
    per partition (sum them — per-device metrics over an SPMD program);
    some backends return None.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: Dict[str, float] = {}
    for part in cost:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
    return out
