"""Version-compat shims over the installed JAX.

The codebase targets the shard_map/cost_analysis API surface of recent
JAX, but must run on whatever the container ships (currently 0.4.37).
Every call site goes through these helpers instead of probing
``jax.<attr>`` itself, so a JAX upgrade changes exactly one file.

* :data:`shard_map` — ``jax.shard_map`` when present (>= 0.6), else
  ``jax.experimental.shard_map.shard_map``.
* :func:`pvary` — mark a value device-varying over mesh axes. Newer
  shard_map requires the annotation (``jax.lax.pvary`` /
  ``jax.lax.pcast``); older shard_map has no such notion, so the shim
  degrades to identity (pair with ``shard_map_kwargs`` below, which
  disables replication checking there).
* :func:`shard_map_kwargs` — extra kwargs for :data:`shard_map` on this
  JAX version (``check_rep=False`` on old JAX, where device-varying
  carries would otherwise fail the replication checker).
* :func:`cost_analysis_dict` — ``Compiled.cost_analysis()`` normalized
  to one flat dict. Depending on version it returns a dict, a list with
  one dict per partition, or None.
* :func:`enable_fast_cpu_scan` — select the XLA:CPU runtime that keeps
  the emulator's long scalar-carry scans fast (see docstring). Call it
  at process entry, before the first jax computation; calling it after
  the backend initialized raises (the flag would be silently ignored).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    _NEW_SHARD_MAP = True
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401
    _NEW_SHARD_MAP = False


def shard_map_kwargs() -> Dict[str, Any]:
    """Extra kwargs to pass to :data:`shard_map` on this JAX version."""
    return {} if _NEW_SHARD_MAP else {"check_rep": False}


def pvary(x, axis_names):
    """Mark ``x`` device-varying over ``axis_names`` inside shard_map."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_names, to="varying")
    return x  # old shard_map: no varying-ness tracking (check_rep=False)


def enable_fast_cpu_scan() -> bool:
    """Select the XLA:CPU runtime that keeps long scalar-carry scans fast.

    The thunk runtime (jaxlib >= 0.4.32 default) executes each of the
    ~100 tiny ops in the emulator's scan body through its intra-op
    thread pool and defeats in-place dynamic-update-slice on the scan
    carry; for an 8k-slot emulation that is ~30 us of synchronization
    per slot — a 30-40x steady-state slowdown on the batched engine
    (measured in ``benchmarks/run.py --section sim_speed``). The legacy
    inline runtime has neither problem. Matmul-heavy model code is
    unaffected either way (both dispatch to Eigen).

    Must run before the CPU backend is created: returns True when the
    flag is (now) in effect for future compilations, and raises
    ``RuntimeError`` when the backend already initialized without it —
    the flag would be silently ignored and every emulation scan would
    quietly run ~30x slower, so a late call is a programming error (fix
    the call order), not a condition to limp past. Returns False only
    when the operator explicitly pinned the thunk runtime on via
    ``XLA_FLAGS`` (their call; warn and respect it). Known caveat: the
    legacy runtime does not populate per-op ``cost_analysis()``
    metrics, so flops-accounting tools (``repro.launch.dryrun``)
    should not run under it.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" in flags:
        if "xla_cpu_use_thunk_runtime=false" in flags:
            return True  # operator already pinned the fast runtime
        import warnings
        warnings.warn(
            "XLA_FLAGS pins xla_cpu_use_thunk_runtime on — emulation "
            "scans will run ~30x slower steady-state", stacklevel=2)
        return False
    try:
        from jax._src import xla_bridge
        backend_up = bool(xla_bridge._backends)
    except (ImportError, AttributeError):  # pragma: no cover - API moved
        backend_up = False
    if backend_up:  # flag would be silently ignored — refuse loudly
        raise RuntimeError(
            "enable_fast_cpu_scan() called after the JAX backend "
            "initialized (e.g. after importing repro.core.emulator or "
            "running any jax computation) — the XLA_FLAGS it sets would "
            "be ignored and emulation scans would run on the slow thunk "
            "runtime. Call it first thing at process entry, before any "
            "repro.core import.")
    os.environ["XLA_FLAGS"] = \
        (flags + " --xla_cpu_use_thunk_runtime=false").strip()
    return True


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as one flat {metric: value} dict.

    Newer JAX returns a single dict; 0.4.x returns a list with one dict
    per partition (sum them — per-device metrics over an SPMD program);
    some backends return None.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: Dict[str, float] = {}
    for part in cost:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
    return out
