"""Version-compat shims over the installed JAX.

The codebase targets the shard_map/cost_analysis API surface of recent
JAX, but must run on whatever the container ships (currently 0.4.37).
Every call site goes through these helpers instead of probing
``jax.<attr>`` itself, so a JAX upgrade changes exactly one file.

* :data:`shard_map` — ``jax.shard_map`` when present (>= 0.6), else
  ``jax.experimental.shard_map.shard_map``.
* :func:`pvary` — mark a value device-varying over mesh axes. Newer
  shard_map requires the annotation (``jax.lax.pvary`` /
  ``jax.lax.pcast``); older shard_map has no such notion, so the shim
  degrades to identity (pair with ``shard_map_kwargs`` below, which
  disables replication checking there).
* :func:`shard_map_kwargs` — extra kwargs for :data:`shard_map` on this
  JAX version (``check_rep=False`` on old JAX, where device-varying
  carries would otherwise fail the replication checker).
* :func:`cost_analysis_dict` — ``Compiled.cost_analysis()`` normalized
  to one flat dict. Depending on version it returns a dict, a list with
  one dict per partition, or None.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    _NEW_SHARD_MAP = True
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401
    _NEW_SHARD_MAP = False


def shard_map_kwargs() -> Dict[str, Any]:
    """Extra kwargs to pass to :data:`shard_map` on this JAX version."""
    return {} if _NEW_SHARD_MAP else {"check_rep": False}


def pvary(x, axis_names):
    """Mark ``x`` device-varying over ``axis_names`` inside shard_map."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_names, to="varying")
    return x  # old shard_map: no varying-ness tracking (check_rep=False)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as one flat {metric: value} dict.

    Newer JAX returns a single dict; 0.4.x returns a list with one dict
    per partition (sum them — per-device metrics over an SPMD program);
    some backends return None.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: Dict[str, float] = {}
    for part in cost:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
    return out
