"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-built program (layer stacks, microbatching, chunked attention) is
undercounted by its trip count. This analyzer parses the (SPMD,
per-device) HLO, recovers each while loop's trip count from its
condition, and propagates flops / HBM bytes / per-kind collective bytes
with multipliers: cost(while) = trips * cost(body).

Covered ops: dot (flops from contracting dims), fusion (recurse), while,
conditional (max branch), call, collectives, elementwise/copy/gather...
(bytes = operands + result). Validated against hand-counted scans in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple result shapes may contain /*index=N*/ comments ('=' inside parens)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)(\(.*)$")
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
                        r"%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    params: Dict[str, str]  # param name -> shape str


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        header = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->", line)
        if header and "{" in line and "=" not in line.split("(")[0]:
            params = {}
            for p in header.group(2).split(","):
                p = p.strip()
                if not p:
                    continue
                pname = p.split(":")[0].strip().lstrip("%")
                pshape = p.split(":", 1)[1] if ":" in p else ""
                params[pname] = pshape
            cur = Computation(header.group(1), [], params)
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    _, rdims = _shape_dims(op.shape)
    out_elems = 1
    for d in rdims:
        out_elems *= d
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    lhs = next((o for o in operands if o in shapes), None)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if lhs and cm:
        _, ldims = _shape_dims(shapes[lhs])
        for i in cm.group(1).split(","):
            if i and int(i) < len(ldims):
                k *= ldims[int(i)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation, comps) -> int:
    """Recover N from the canonical `iv < N` loop condition."""
    consts = {}
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.kind == "compare":
            ops_ = _OPERAND_RE.findall(op.rest)
            for o in ops_:
                if o in consts:
                    return max(consts[o], 1)
    return 1


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "while", "conditional", "call", "fusion", "custom-call",
               "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
               "optimization-barrier"}


def analyze(text: str) -> Dict[str, float]:
    comps = parse_computations(text)
    cache: Dict[str, Dict[str, float]] = {}

    entry = None
    for name, c in comps.items():
        if "main" in name or entry is None:
            if entry is None or "main" in name:
                entry = name

    def cost_of(cname: str, depth=0) -> Dict[str, float]:
        if cname in cache:
            return cache[cname]
        c = comps.get(cname)
        out = {"flops": 0.0, "bytes": 0.0}
        out.update({k: 0.0 for k in COLLECTIVES})
        if c is None or depth > 50:
            return out
        cache[cname] = out  # guard recursion
        shapes = dict(c.params)
        for op in c.ops:
            shapes[op.name] = op.shape
        for op in c.ops:
            kind = op.kind
            if kind in ("dot",):
                out["flops"] += _dot_flops(op, shapes)
                out["bytes"] += _shape_bytes(op.shape)
                for o in set(_OPERAND_RE.findall(op.rest)):
                    if o in shapes:
                        out["bytes"] += _shape_bytes(shapes[o])
            elif kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                # XLA annotates the trip count it proved; fall back to
                # parsing the canonical `iv < N` condition
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', op.rest)
                if tm:
                    trips = max(int(tm.group(1)), 1)
                else:
                    cm_ = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    trips = _trip_count(comps[cm_.group(1)], comps) if cm_ and \
                        cm_.group(1) in comps else 1
                if bm and bm.group(1) in comps:
                    sub = cost_of(bm.group(1), depth + 1)
                    for k in out:
                        out[k] += trips * sub[k]
            elif kind in ("fusion", "call", "custom-call"):
                bm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.rest)
                if bm and bm.group(1) in comps:
                    sub = cost_of(bm.group(1), depth + 1)
                    for k in out:
                        if k != "bytes":  # fused intermediates stay on-chip
                            out[k] += sub[k]
                # HBM traffic of a fusion = its operands + result only
                out["bytes"] += _shape_bytes(op.shape)
                for o in set(_OPERAND_RE.findall(op.rest.split(", calls=")[0])):
                    if o in shapes:
                        out["bytes"] += _shape_bytes(shapes[o])
            elif kind == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.rest)
                subs = [cost_of(b, depth + 1) for b in branches if b in comps]
                if subs:
                    for k in out:
                        out[k] += max(s[k] for s in subs)
            elif any(kind.startswith(cname2) for cname2 in COLLECTIVES):
                base = next(cn for cn in COLLECTIVES if kind.startswith(cn))
                if kind.endswith("-done"):
                    continue
                nbytes = _shape_bytes(op.shape)
                out[base] += nbytes
                out["bytes"] += nbytes
            elif kind in _SKIP_BYTES:
                continue
            elif kind == "dynamic-update-slice":
                # in-place update touches the update region, not the buffer
                ops_ = _OPERAND_RE.findall(op.rest)
                upd = ops_[1] if len(ops_) > 1 and ops_[1] in shapes else None
                out["bytes"] += 2 * (_shape_bytes(shapes[upd]) if upd
                                     else _shape_bytes(op.shape) // 8)
            elif kind in ("dynamic-slice", "slice", "gather"):
                out["bytes"] += 2 * _shape_bytes(op.shape)  # read region + write
            else:
                # elementwise / reduce / copy / ...: operands + result
                out["bytes"] += _shape_bytes(op.shape)
                for o in set(_OPERAND_RE.findall(op.rest)):
                    if o in shapes:
                        out["bytes"] += _shape_bytes(shapes[o])
        cache[cname] = out
        return out

    # find the true entry computation (ENTRY marker)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if m and m.group(1) in comps:
        entry = m.group(1)
    res = cost_of(entry)
    res["collective_bytes"] = sum(res[k] for k in COLLECTIVES)
    return res
