"""Train-step construction (mixed precision + ZeRO-1) and the host loop.

``make_train_step`` returns the pure step the launchers jit/lower; the
``Trainer`` host loop adds checkpoint/restart, straggler-aware step
timing, and data ingestion (used by examples and fault-tolerance tests).
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.train import optimizer as opt
from repro.sharding.rules import Rules, set_rules


def _constrain(tree, spec_tree, mesh):
    if mesh is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, spec_tree)


def make_train_step(model, opt_cfg: opt.AdamWConfig, rules: Optional[Rules] = None,
                    compute_dtype=jnp.bfloat16, grad_compressor=None,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches > 1`` scans gradient accumulation over microbatches:
    activation memory scales down by the microbatch count and the
    accumulator lives at ZeRO-1 sharding (reduce-scattered per microbatch).
    """
    mesh = rules.mesh if rules else None
    param_specs = model.param_pspecs(rules) if rules else None
    zero1 = opt.zero1_pspecs(model.defs, rules) if rules else None

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        if grad_compressor == "int8_wire":
            # quantize BEFORE the reduce-scatter so the collective moves
            # int8 (2x fewer bytes than bf16); dequantize on the far side
            from repro.distributed.grad_comp import dequantize, quantize_int8

            q = jax.tree_util.tree_map(
                lambda g: quantize_int8(g.astype(jnp.float32))[0], grads)
            s = jax.tree_util.tree_map(
                lambda g: quantize_int8(g.astype(jnp.float32))[1], grads)
            if rules:
                q = _constrain(q, zero1, mesh)
            grads = jax.tree_util.tree_map(
                lambda qq, ss, g: dequantize(qq, ss).astype(g.dtype),
                q, s, grads)
            return loss, metrics, grads
        if grad_compressor is not None:
            grads = grad_compressor(grads)
        if rules:
            grads = _constrain(grads, zero1, mesh)   # reduce-scatter
        return loss, metrics, grads

    def train_step(state: opt.AdamWState, batch):
        # compute copy: bf16, TP-natural sharding (the ZeRO-1 all-gather)
        params = jax.tree_util.tree_map(lambda x: x.astype(compute_dtype), state.master)
        if rules:
            params = _constrain(params, param_specs, mesh)

        k = num_microbatches
        if k == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:])
                if x.ndim >= 1 and x.shape and x.shape[0] % k == 0 else
                jnp.broadcast_to(x, (k,) + x.shape), batch)
            # fp32 accumulator (ZeRO-1 sharded): bf16 microbatch grads
            # upcast on add, so accumulation error does not grow with k
            acc0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), state.master)
            if rules:
                acc0 = _constrain(acc0, zero1, mesh)

            def body(carry, mbatch):
                acc, lsum = carry
                loss, metrics, grads = grads_of(params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, lsum + loss), metrics

            (grads, lsum), ms = jax.lax.scan(body, (acc0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = lsum / k
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)

        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        new_state, om = opt.apply_update(opt_cfg, state, grads)
        metrics = dict(metrics, loss=loss, **om)
        return new_state, metrics

    return train_step


class Trainer:
    """Host loop: step timing, checkpoint/restart, straggler mitigation.

    Straggler policy: steps are timed against a deadline derived from a
    moving median; a step exceeding ``straggler_factor`` x median is
    logged and counted (on real fleets this triggers re-slicing — here it
    drives the elastic re-mesh hook).
    """

    def __init__(self, model, opt_cfg, rules=None, ckpt_dir=None, ckpt_every=50,
                 straggler_factor=3.0, hooks=None):
        from repro.checkpoint import ckpt as ckpt_mod
        self.model = model
        self.opt_cfg = opt_cfg
        self.rules = rules
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt_mod = ckpt_mod
        self.straggler_factor = straggler_factor
        self.step_times = []
        self.straggler_events = 0
        self.hooks = hooks or {}
        self._step_fn = jax.jit(make_train_step(model, opt_cfg, rules),
                                donate_argnums=(0,))

    def init_state(self, seed=0):
        params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        return opt.init_state(params)

    def restore_or_init(self, seed=0):
        if self.ckpt_dir:
            st = self.ckpt_mod.restore_latest(self.ckpt_dir)
            if st is not None:
                state = self.init_state(seed)
                return self.ckpt_mod.load_into(st, state), True
        return self.init_state(seed), False

    def run(self, state, data_iter, steps, log_every=10):
        set_rules(self.rules)
        history = []
        try:
            for i in range(steps):
                t0 = time.perf_counter()
                batch = next(data_iter)
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step_times.append(dt)
                med = sorted(self.step_times)[len(self.step_times) // 2]
                if len(self.step_times) > 5 and dt > self.straggler_factor * med:
                    self.straggler_events += 1
                    if "on_straggler" in self.hooks:
                        self.hooks["on_straggler"](int(state.step), dt, med)
                history.append(loss)
                if log_every and i % log_every == 0:
                    print(f"step {int(state.step):5d} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if self.ckpt_dir and int(state.step) % self.ckpt_every == 0:
                    self.ckpt_mod.save(self.ckpt_dir, state, int(state.step))
        finally:
            set_rules(None)
        return state, history
