"""AdamW with ZeRO-1 sharded states + mixed-precision master weights.

Optimizer states (fp32 master, m, v) are sharded over the *data* axis on
top of the param's TP spec (ZeRO-1): ``zero1_pspecs`` picks the largest
still-unsharded, divisible dim. The compute copy of the params is bf16
with the TP-natural spec — the cast + resharding is where the per-step
all-gather happens, and the gradient constraint is the reduce-scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import pdefs


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: Any
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def zero1_pspecs(defs, rules):
    """Param spec + extra 'data' sharding on the largest free divisible dim."""
    base = pdefs.pspec_tree(defs, rules.resolve)
    data_size = rules._axis_size(rules._present(("data",)))

    def widen(d: pdefs.ParamDef, spec: P):
        axes = list(spec) + [None] * (len(d.shape) - len(spec))
        used = set()
        for a in axes:
            used.update(a if isinstance(a, tuple) else (a,) if a else ())
        if "data" in used or data_size <= 1:
            return spec
        cands = [(d.shape[i], i) for i in range(len(axes))
                 if axes[i] is None and d.shape[i] % data_size == 0 and d.shape[i] > 1]
        if not cands:
            return spec
        _, i = max(cands)
        axes[i] = "data"
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    return jax.tree_util.tree_map(widen, defs, base, is_leaf=pdefs.is_def)


def init_state(params_fp32) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), params_fp32)
    return AdamWState(step=jnp.zeros((), jnp.int32), master=params_fp32,
                      m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros))


def abstract_state(defs) -> AdamWState:
    t = pdefs.abstract_tree(defs, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), master=t, m=t, v=t)


def state_pspecs(defs, rules) -> AdamWState:
    z = zero1_pspecs(defs, rules)
    return AdamWState(step=P(), master=z, m=z, v=z)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_update(cfg: AdamWConfig, state: AdamWState, grads) -> tuple:
    """grads: fp32, same sharding as master. Returns (new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    flat_p = jax.tree_util.tree_leaves(state.master)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [n[2] for n in new])
    return (AdamWState(step=step, master=new_p, m=new_m, v=new_v),
            {"grad_norm": gn, "lr": lr})
