import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-size model from its config, resolves
shardings, lowers the real step function (train_step incl. optimizer for
train shapes; prefill/decode for serve shapes) against ShapeDtypeStruct
inputs, compiles it, and records memory_analysis / cost_analysis /
per-device collective bytes into a JSON artifact under
``artifacts/dryrun/``. No arrays are ever allocated.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4_9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, all_configs, applicable_shapes, get_config, get_shape
from repro.launch.mesh import HW, make_production_mesh
from repro.models import model_zoo
from repro.sharding import rules as rules_mod
from repro.train import optimizer as opt
from repro.train.trainer import make_train_step
from repro.utils import hlo as hlo_util
from repro.utils import hlo_cost
from repro.utils.jax_compat import cost_analysis_dict

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _sharded(mesh, spec_tree, sds_tree):
    return jax.tree_util.tree_map(
        lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=NamedSharding(mesh, spec)),
        sds_tree, spec_tree)


# microbatch counts chosen so train cells fit 16 GiB/chip (also keeps the
# accumulation scan >= 16 trips, which XLA:CPU would otherwise unroll)
MICROBATCHES = {
    "jamba_v0_1_52b": 16, "llava_next_34b": 16, "qwen3_moe_30b_a3b": 16,
    "glm4_9b": 4, "qwen3_8b": 4, "gemma_7b": 4, "rwkv6_3b": 4,
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool, remat=True,
               prefs=None, extra_tag="", microbatches=None, kv_dtype=None,
               grad_compress=False, cfg_overrides=None, moe_overrides=None):
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    if moe_overrides and cfg.moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, **moe_overrides))
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_mod.Rules(mesh, prefs=prefs)
    cdt = {"int8": jnp.int8, "bf16": jnp.bfloat16, None: jnp.bfloat16}[kv_dtype]
    model = model_zoo.build(cfg, s_max=shape.seq_len, remat=remat,
                            cache_dtype=cdt)
    t0 = time.perf_counter()
    rules_mod.set_rules(rules)
    try:
        ins = model.input_specs(shape)
        in_pspecs = model.input_pspecs(shape, rules)
        if shape.kind == "train":
            k = microbatches if microbatches is not None else MICROBATCHES.get(arch, 1)
            gc = "int8_wire" if grad_compress else None
            step = make_train_step(model, opt.AdamWConfig(), rules,
                                   num_microbatches=k, grad_compressor=gc)
            state_sds = opt.abstract_state(model.defs)
            state_ps = opt.state_pspecs(model.defs, rules)
            args_sds = (_sharded(mesh, state_ps, state_sds),
                        _sharded(mesh, in_pspecs, ins))
            fn = jax.jit(step, donate_argnums=(0,))
        elif shape.kind == "prefill":
            # serve params are 2D-sharded (TP x data): weights stream once
            # per token, so gather-on-use beats replicated residency
            pspecs = opt.zero1_pspecs(model.defs, rules)
            params_sds = model.abstract_params(jnp.bfloat16)
            args_sds = (_sharded(mesh, pspecs, params_sds),
                        _sharded(mesh, in_pspecs, ins))
            fn = jax.jit(model.prefill_fn)
        else:  # decode
            pspecs = opt.zero1_pspecs(model.defs, rules)
            params_sds = model.abstract_params(jnp.bfloat16)
            args_sds = (_sharded(mesh, pspecs, params_sds),
                        _sharded(mesh, in_pspecs["cache"], ins["cache"]),
                        _sharded(mesh, in_pspecs["token"], ins["token"]),
                        _sharded(mesh, P(), ins["pos"]))
            fn = jax.jit(model.decode_fn, donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(*args_sds)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
    finally:
        rules_mod.set_rules(None)

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    txt = compiled.as_text()
    coll = hlo_util.collective_bytes(txt)
    # loop-aware accounting: XLA cost_analysis counts while bodies once;
    # hlo_cost multiplies by proven trip counts (see utils/hlo_cost.py)
    adj = hlo_cost.analyze(txt)
    chips = mesh.devices.size

    mem_rec = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        mem_rec[f] = int(getattr(mem, f, 0) or 0)
    per_dev_flops = float(adj["flops"])
    per_dev_bytes = float(adj["bytes"])
    per_dev_coll = float(adj["collective_bytes"])

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips, "kind": shape.kind,
        "n_params": model.n_params(),
        "microbatches": (microbatches if microbatches is not None
                         else MICROBATCHES.get(arch, 1)) if shape.kind == "train" else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "per_device": {
            "flops": per_dev_flops,
            "bytes_accessed": per_dev_bytes,
            "collective_bytes": per_dev_coll,
            "collectives": {k: adj[k] for k in hlo_cost.COLLECTIVES},
            "xla_cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                                      "bytes": float(cost.get("bytes accessed", 0.0)),
                                      "collectives_unrolled_text": coll},
        },
        "roofline_s": {
            "compute": per_dev_flops / HW["peak_flops_bf16"],
            "memory": per_dev_bytes / HW["hbm_bw"],
            "collective": per_dev_coll / HW["ici_link_bw"],
        },
        "layout": rules.layout_report(),
        "tag": extra_tag,
    }
    terms = rec["roofline_s"]
    rec["dominant"] = max(terms, key=terms.get)
    return rec


def artifact_path(arch, shape, multi_pod, tag=""):
    mesh = "mp" if multi_pod else "sp"
    suffix = f"-{tag}" if tag else ""
    return os.path.join(ART_DIR, f"{arch}--{shape}--{mesh}{suffix}.json")


def run_cell(arch, shape, multi_pod, force=False, tag="", **kw):
    os.makedirs(ART_DIR, exist_ok=True)
    path = artifact_path(arch, shape, multi_pod, tag)
    if os.path.exists(path) and not force:
        print(f"[skip] {path}")
        return json.load(open(path))
    try:
        rec = lower_cell(arch, shape, multi_pod, extra_tag=tag, **kw)
        print(f"[ok] {arch} {shape} {'mp' if multi_pod else 'sp'} "
              f"compile={rec['compile_s']}s dominant={rec['dominant']}")
    except Exception as e:  # record failures so the sweep reports them
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:], "tag": tag}
        print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    fails = 0
    if args.all:
        for arch, cfg in all_configs().items():
            for s in applicable_shapes(cfg):
                for mp in meshes:
                    rec = run_cell(arch, s.name, mp, force=args.force, tag=args.tag)
                    fails += "error" in rec
    else:
        assert args.arch and args.shape
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, mp, force=args.force, tag=args.tag)
            fails += "error" in rec
            if "error" in rec:
                print(rec.get("trace", ""))
    print(f"done, failures={fails}")
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
