"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e-flavored hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_link_bw": 50e9,         # bytes/s per link (~, one direction)
    "hbm_bytes": 16 * 2 ** 30,   # 16 GiB per chip
}
