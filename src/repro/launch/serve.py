"""Serving launcher: batched generation for any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma_7b --preset tiny \
      --batch 4 --new 16

Also fronts the sweep service (shared multi-client campaign server):

  PYTHONPATH=src python -m repro.launch.serve sweep --port 7421

which is equivalent to ``python -m repro.service`` (see that module for
the full flag set).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import SSMConfig, get_config
from repro.launch.train import PRESETS
from repro.models import model_zoo
from repro.serve.engine import ServeEngine


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "sweep":
        from repro.service.__main__ import main as sweep_main
        sweep_main(sys.argv[2:])
        return
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if PRESETS[args.preset]:
        over = dict(PRESETS[args.preset])
        if cfg.attn_free:
            over["n_kv_heads"] = over["n_heads"]
            over["ssm"] = SSMConfig(chunk=16)
        cfg = cfg.scaled(**over)
    s_max = args.prompt_len + args.new
    model = model_zoo.build(cfg, s_max=s_max)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, s_max=s_max)

    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len))
    t0 = time.perf_counter()
    outs = engine.generate_batch(prompts, args.new)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s, timeouts={engine.timeouts})")
    print("sample:", outs[0].tolist())


if __name__ == "__main__":
    main()
