"""Training launcher: ``--arch <id>`` selects an assigned architecture.

On a real TPU fleet this runs under the production mesh
(``make_production_mesh``); on a dev box it uses whatever local devices
exist. Reduced presets make any arch runnable anywhere (full configs are
exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.train --arch glm4_9b --preset tiny \
      --steps 50 --ckpt /tmp/glm4_run [--resume] [--microbatches 4] \
      [--grad-compress]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo
from repro.sharding.rules import Rules
from repro.train import optimizer as opt
from repro.train.trainer import Trainer

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                 vocab_size=512, head_dim=32),
    "small": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
                  vocab_size=8192, head_dim=64),
    "full": {},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if PRESETS[args.preset]:
        over = dict(PRESETS[args.preset])
        if cfg.attn_free:
            over["n_kv_heads"] = over["n_heads"]
        cfg = cfg.scaled(**over)
    model = model_zoo.build(cfg, s_max=args.seq)
    print(f"{cfg.name} [{args.preset}] params={model.n_params():,} "
          f"devices={len(jax.devices())}")

    rules = None
    if len(jax.devices()) > 1:
        rules = Rules(make_host_mesh(model=args.model_parallel))

    from repro.train.trainer import make_train_step
    trainer = Trainer(model, opt.AdamWConfig(lr=args.lr, warmup=10,
                                             total_steps=max(args.steps, 100)),
                      rules=rules, ckpt_dir=args.ckpt, ckpt_every=25)
    if args.microbatches > 1 or args.grad_compress:
        trainer._step_fn = jax.jit(make_train_step(
            model, trainer.opt_cfg, rules,
            num_microbatches=args.microbatches,
            grad_compressor="int8_wire" if args.grad_compress else None),
            donate_argnums=(0,))
    state, restored = trainer.restore_or_init()
    start = int(state.step)
    if restored:
        print(f"resumed from step {start}")
    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    loader = ShardedLoader(src, start_step=start)
    state, hist = trainer.run(state, iter(loader), max(args.steps - start, 0),
                              log_every=10)
    if hist:
        print(f"loss {hist[0]:.4f} -> {hist[-1]:.4f}; "
              f"stragglers={trainer.straggler_events}")


if __name__ == "__main__":
    main()
