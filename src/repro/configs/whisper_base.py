"""whisper-base [audio] — enc-dec, conv frontend (stub frame embeds).
[arXiv:2212.04356; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    act="gelu", qkv_bias=True, rope_theta=0.0,  # learned positions, no rope
    n_enc_layers=6, n_frames=1500,
    source="arXiv:2212.04356",
)
