"""Architecture/shape config system.

Every assigned architecture is a module exposing ``CONFIG: ArchConfig``.
``get_config(name)`` resolves from the registry; ``--arch <id>`` in the
launchers goes through here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden dim
    every: int = 1             # MoE on layers where (idx % every == every-1)
    capacity_factor: float = 1.25
    group_size: int = 256      # tokens per dispatch group (bounds dispatch tensor)
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 => d_model // 16
    chunk: int = 256           # chunked selective-scan block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                  # dense-MLP hidden (per-expert dim lives in moe)
    vocab_size: int
    head_dim: int = 0          # 0 => d_model // n_heads
    act: str = "swiglu"        # swiglu | geglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1        # hybrid: attention on layers where idx % attn_every == attn_every-1
    n_enc_layers: int = 0      # encdec only
    n_frames: int = 0          # encdec audio frames (stub frontend)
    n_patches: int = 0         # vlm patch prefix (stub frontend)
    attn_free: bool = False    # rwkv: no attention at all
    source: str = ""           # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a TP/MXU-friendly multiple (loss masks the pad)."""
        m = 2048
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or max(self.d_model // 16, 1)

    @property
    def full_attention(self) -> bool:
        """True when long-context decode is quadratic/full-KV (=> skip long_500k)."""
        return not (self.attn_free or self.attn_every > 1)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec, not enc-only)

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced config of the same family for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

ARCH_IDS = (
    "glm4_9b",
    "qwen2_1_5b",
    "qwen3_8b",
    "gemma_7b",
    "llava_next_34b",
    "whisper_base",
    "jamba_v0_1_52b",
    "granite_moe_1b_a400m",
    "qwen3_moe_30b_a3b",
    "rwkv6_3b",
)

# public ids use dashes (assignment table); module names use underscores
def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    mod_name = _norm(name)
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


def applicable_shapes(cfg: ArchConfig):
    """The (arch x shape) cells that are well-defined per the assignment rules."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and cfg.full_attention:
            continue  # needs sub-quadratic attention; skip noted in DESIGN.md
        out.append(s)
    return tuple(out)
