"""rwkv6-3b [ssm] — Finch, data-dependent decay, attn-free.
[arXiv:2404.05892; hf]"""
from repro.configs import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # wkv heads, head_dim=64
    d_ff=8960, vocab_size=65536, head_dim=64,
    act="relu_sq",  # rwkv channel-mix uses squared relu
    rope_theta=0.0,
    ssm=SSMConfig(chunk=64),
    attn_free=True,
    source="arXiv:2404.05892",
)
