"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]"""
from repro.configs import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    act="swiglu", rope_theta=0.0,  # jamba attn layers use no positional encoding
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    source="arXiv:2403.19887",
)
