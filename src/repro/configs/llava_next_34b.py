"""llava-next-34b [vlm] — anyres tiling (stub 576-patch prefix).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    act="swiglu", rope_theta=1e6,
    n_patches=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
