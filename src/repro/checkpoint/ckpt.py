"""Sharded checkpointing: per-leaf npy shards + manifest, async writer.

Layout: ``<dir>/step_<n>/<leaf-path>.npy`` + ``manifest.json``. Writes
go through a temp directory + atomic rename, so a crash mid-write never
corrupts the latest checkpoint (restart safety). ``save(..., async_=True)``
hands serialization to a background thread — the train loop keeps
stepping while the previous state persists (fault-tolerance substrate).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key or "leaf"] = leaf
    return out, treedef


def save(ckpt_dir: str, state: Any, step: int, async_: bool = False,
         keep: int = 3) -> Optional[threading.Thread]:
    """Write state at ``step``. Returns the writer thread when async."""
    leaves, _ = _flatten(state)
    host = {k: np.asarray(v) for k, v in leaves.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
            manifest["leaves"][k] = {"shape": list(v.shape), "dtype": str(v.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        return th
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_latest(ckpt_dir: str) -> Optional[dict]:
    """Returns {leaf_key: np.ndarray} of the newest intact checkpoint."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    out = {k: np.load(os.path.join(d, k + ".npy"))
           for k in manifest["leaves"]}
    out["__step__"] = step
    return out


def load_into(leaves: dict, state_template: Any) -> Any:
    """Rehydrate a pytree of the template's structure from restored leaves."""
    flat, treedef = _flatten(state_template)
    vals = []
    for k, tmpl in flat.items():
        v = leaves[k]
        assert tuple(v.shape) == tuple(np.shape(tmpl)), (k, v.shape, np.shape(tmpl))
        vals.append(jax.numpy.asarray(v, dtype=tmpl.dtype))
    # rebuild in the template's flatten order
    paths = jax.tree_util.tree_flatten_with_path(state_template)[0]
    rebuilt = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_template), vals)
    return rebuilt
