"""Elastic re-meshing: reshard a training state onto a new mesh.

On node loss (or growth) the runtime rebuilds the mesh from the healthy
device set and moves the ZeRO-1-sharded state onto it. Sharding specs
re-resolve under the new axis sizes (the divisibility fallbacks in
``sharding.rules`` absorb shrunken axes); data is moved with
``jax.device_put`` which reshards across the old/new layouts.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import Rules
from repro.train import optimizer as opt


def remesh_state(state, model, new_mesh: Mesh):
    """Reshard an AdamWState onto new_mesh; returns (state, new_rules)."""
    rules = Rules(new_mesh)
    specs = opt.state_pspecs(model.defs, rules)

    def move(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    new_state = jax.tree_util.tree_map(move, state, specs)
    return new_state, rules


def healthy_mesh(n_devices: int, model_parallel: int):
    """Build the largest (data, model) mesh from surviving devices."""
    devs = jax.devices()[:n_devices]
    model_parallel = min(model_parallel, len(devs))
    data = len(devs) // model_parallel
    return Mesh(
        __import__("numpy").array(devs[:data * model_parallel])
        .reshape(data, model_parallel), ("data", "model"))
