"""GPipe-style pipeline parallelism over a mesh axis (e.g. "pod").

``pipeline_apply`` runs S stages over M microbatches in S+M-1 ticks via
``shard_map`` + ``collective_permute`` hand-off: stage s computes
microbatch m at tick s+m, passing activations ring-wise. Bubble fraction
(S-1)/(S+M-1) — choose M >= 4S in production. The jamba/llava-scale
models map their layer groups onto stages with this scheduler; the unit
test validates exact equality with the sequential stack.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.jax_compat import pvary, shard_map, shard_map_kwargs


def pipeline_apply(params_stacked, x_mb, stage_fn, mesh, axis: str = "pod"):
    """params_stacked: pytree with leading dim = n_stages (sharded on axis).
    x_mb: [M, mb, ...] microbatched input (replicated). Returns [M, mb, ...]
    after all stages, computed with the pipelined schedule."""
    S = mesh.shape[axis]
    M = x_mb.shape[0]

    pspec_params = jax.tree_util.tree_map(lambda _: P(axis), params_stacked)

    @partial(shard_map, mesh=mesh,
             in_specs=(pspec_params, P()), out_specs=P(),
             **shard_map_kwargs())
    def run(params_local, x_all):
        # params_local leaves: [1, ...] — this device's stage
        p = jax.tree_util.tree_map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        T = S + M - 1
        buf = jnp.zeros_like(x_all[0])          # current inbound activation
        outs = jnp.zeros_like(x_all)
        # carries become device-varying after the ppermute; mark them so
        buf = pvary(buf, (axis,))
        outs = pvary(outs, (axis,))

        def tick(carry, t):
            buf, outs = carry
            m = t - sid                          # microbatch index at this stage
            active = (m >= 0) & (m < M)
            x_in = jnp.where(sid == 0,
                             x_all[jnp.clip(t, 0, M - 1)], buf)
            y = stage_fn(p, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage writes its finished microbatch
            outs = jnp.where((sid == S - 1) & active,
                             outs.at[jnp.clip(m, 0, M - 1)].set(y), outs)
            # ring hand-off to the next stage
            buf = jax.lax.ppermute(y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # every device returns the same gathered result: sum over stages
        # (only the last stage wrote non-zeros)
        outs = jax.lax.psum(outs, axis)
        return outs

    return run(params_stacked, x_mb)


def sequential_apply(params_stacked, x_mb, stage_fn):
    """Reference: run all stages sequentially over all microbatches."""
    def one_mb(x):
        def body(x, p):
            return stage_fn(p, x), None
        x, _ = jax.lax.scan(body, x, params_stacked)
        return x
    return jax.vmap(one_mb)(x_mb)
