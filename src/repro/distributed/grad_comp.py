"""Error-feedback int8 gradient compression (opt-in).

Per-leaf symmetric int8 quantization with a persistent error-feedback
accumulator: the quantization residual is carried into the next step, so
the *accumulated* update is unbiased (EF-SGD style). Applied before the
ZeRO-1 reduce-scatter, it cuts gradient collective bytes ~2x vs bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def make_ef_compressor():
    """Returns (compress(grads, ef_state) -> (grads', ef_state'), init_ef)."""

    def init_ef(grads_like):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    def compress(grads, ef):
        def one(g, e):
            v = g.astype(jnp.float32) + e
            q, s = quantize_int8(v)
            deq = dequantize(q, s)
            return deq.astype(g.dtype), v - deq
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(ef)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        g2 = jax.tree_util.tree_unflatten(tdef, [p[0] for p in pairs])
        e2 = jax.tree_util.tree_unflatten(tdef, [p[1] for p in pairs])
        return g2, e2

    return compress, init_ef


def simple_compressor(grads):
    """Stateless variant for make_train_step(grad_compressor=...)."""
    def one(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize(q, s).astype(g.dtype)
    return jax.tree_util.tree_map(one, grads)
