"""Serve engine: prefill + decode with KV cache, continuous batching,
RowClone-backed page forks, and request-level straggler timeouts.

The engine drives the model zoo's pure ``prefill_fn``/``decode_fn``.
``fork_request`` duplicates a finished prompt's KV pages for N
continuations — the serving-side bulk-copy the RowClone case study
models (``kernels.rowclone_copy`` is its on-TPU analogue; the emulator's
``kv_fork_trace`` its DRAM-level cost model).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def pad_cache_to(cache, s_max: int):
    """Pad attention-cache leaves (G,B,S,KV,hd) out to s_max along S.

    Only k/v-named leaves are touched — recurrent states (mamba conv/h,
    rwkv wkv) keep their shapes."""
    def one(path, x):
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v", "self_k", "self_v") and x.ndim == 5 \
                and x.shape[2] < s_max:
            pad = [(0, 0)] * 5
            pad[2] = (0, s_max - x.shape[2])
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(one, cache)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    started: float = 0.0


class ServeEngine:
    def __init__(self, model, params, s_max: int, straggler_timeout_s: float = 30.0):
        self.model = model
        self.params = params
        self.s_max = s_max
        self.timeout = straggler_timeout_s
        self._prefill = jax.jit(model.prefill_fn)
        self._decode = jax.jit(model.decode_fn)
        self.timeouts = 0

    def generate(self, prompt: np.ndarray, max_new: int, greedy=True) -> List[int]:
        """Single-request generation (batch dim 1)."""
        B = 1
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        if self.model.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, self.model.cfg.n_patches, self.model.cfg.d_model), jnp.float32)
        if self.model.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, self.model.cfg.n_frames, self.model.cfg.d_model), jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        cache = pad_cache_to(cache, self.s_max)
        pos = prompt.shape[-1]
        tok = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)[:, None]
        out = [int(tok[0, 0])]
        t0 = time.perf_counter()
        for _ in range(max_new - 1):
            if time.perf_counter() - t0 > self.timeout:
                self.timeouts += 1   # straggler mitigation: give up the tail
                break
            logits, cache = self._decode(self.params, cache,
                                         tok.astype(jnp.int32), jnp.int32(pos))
            tok = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)[:, None]
            out.append(int(tok[0, 0]))
            pos += 1
        return out

    def _modality_stubs(self, batch, B):
        if self.model.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, self.model.cfg.n_patches, self.model.cfg.d_model), jnp.float32)
        if self.model.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, self.model.cfg.n_frames, self.model.cfg.d_model), jnp.float32)
        return batch

    def generate_batch(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Batched generation, all prompts same length (continuous batch)."""
        B, S0 = prompts.shape
        batch = self._modality_stubs({"tokens": jnp.asarray(prompts)}, B)
        logits, cache = self._prefill(self.params, batch)
        cache = pad_cache_to(cache, self.s_max)
        pos = S0
        tok = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)[:, None]
        outs = [np.asarray(tok)[:, 0]]
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         tok.astype(jnp.int32), jnp.int32(pos))
            tok = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)[:, None]
            outs.append(np.asarray(tok)[:, 0])
            pos += 1
        return np.stack(outs, axis=1)  # [B, max_new]

    def fork_cache(self, cache, n: int, use_kernel: bool = False):
        """Duplicate a batch-1 cache into n continuations (beam/prefix fork).

        With ``use_kernel`` the copy goes through the rowclone_copy Pallas
        kernel (interpret mode on CPU) — the TPU analogue of in-DRAM copy."""
        def one(x):
            if x.ndim >= 2 and x.shape[1] == 1:
                reps = [1] * x.ndim
                reps[1] = n
                if use_kernel and x.ndim == 5:
                    from repro.kernels import ops as kops
                    flat = x.reshape(x.shape[0], -1)
                    copies = [kops.rowclone_copy(flat).reshape(x.shape)
                              for _ in range(n)]
                    return jnp.concatenate(copies, axis=1)
                return jnp.tile(x, reps)
            return x
        return jax.tree_util.tree_map(one, cache)
