"""Case study (paper Sec. 8): tRCD reduction — characterize the device,
build the weak-row Bloom filter, run PolyBench-like workloads end-to-end.

  PYTHONPATH=src python examples/trcd_case_study.py

Second runs start fast: XLA executables persist in artifacts/xla_cache
(enable_persistent_compile_cache below), so a fresh process skips the
cold compiles; the base + reduced arms of the whole kernel suite then
run through the overlapped campaign executor.
"""
import warnings

warnings.filterwarnings("ignore")

# both must precede the first jax computation (backend init)
from repro.utils.jax_compat import (enable_fast_cpu_scan,
                                    enable_persistent_compile_cache)

enable_fast_cpu_scan()
enable_persistent_compile_cache()

import numpy as np

from repro.core import traces
from repro.core.dram import Geometry
from repro.core.profiling import DeviceModel
from repro.core.techniques import TRCDReduction
from repro.core.timescale import JETSON_NANO


def main():
    geo = Geometry()
    dev = DeviceModel(geo)
    print(f"device model: {100*(1-dev.weak_fraction()):.1f}% strong rows "
          f"(paper: 84.5%), min tRCD {dev.min_trcd_ns.min():.1f} ns")

    t = TRCDReduction(JETSON_NANO, dev)
    t.characterize()
    s = t.safety_check()
    print(f"bloom filter: false negatives={s['false_negatives']} (must be 0), "
          f"FPR={s['false_positive_rate']:.3%}")

    print(f"\n{'kernel':>14s} {'speedup':>8s}")
    names, trs = [], []
    for i, kern in enumerate(traces.POLYBENCH[:12]):
        tr, _ = traces.polybench_trace(kern, geo, max_accesses=6000, seed=i)
        if tr is None:
            continue
        names.append(kern.name)
        trs.append(tr)
    # base + reduced arms for every kernel in one batched campaign
    # (TRCDReduction.evaluate_traces -> Campaign -> emulator.run_many)
    speedups = []
    for name, r in zip(names, t.evaluate_traces(trs)):
        speedups.append(r["speedup"])
        print(f"{name:>14s} {r['speedup']:>7.3f}x")
    print(f"{'avg':>14s} {np.mean(speedups):>7.3f}x  (paper avg: 1.0275x)")


if __name__ == "__main__":
    main()
