"""End-to-end training driver: any assigned arch at a configurable scale.

Default preset trains a ~100M-param qwen2-family model for a few hundred
steps (use --steps/--preset to size to your machine; 'tiny' runs in ~a
minute on CPU). Fault tolerance: kill it mid-run and restart with the
same command — it resumes from the newest intact checkpoint.

  PYTHONPATH=src python examples/train_lm.py --arch qwen2_1_5b --preset tiny
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.models import model_zoo
from repro.train import optimizer as opt
from repro.train.trainer import Trainer

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                 vocab_size=512, head_dim=32, seq=64, batch=8),
    "10m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                vocab_size=4096, head_dim=32, seq=128, batch=8),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                 vocab_size=32768, head_dim=64, seq=256, batch=16),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    seq, batch = p.pop("seq"), p.pop("batch")
    cfg = get_config(args.arch).scaled(**p)
    model = model_zoo.build(cfg, s_max=seq)
    print(f"{cfg.name} preset={args.preset}: {model.n_params():,} params")

    src = SyntheticLM(cfg.vocab_size, seq, batch, seed=0)
    trainer = Trainer(model, opt.AdamWConfig(lr=3e-3, warmup=20,
                                             total_steps=max(args.steps, 100)),
                      ckpt_dir=args.ckpt, ckpt_every=25)
    state, restored = trainer.restore_or_init()
    start = int(state.step)
    if restored:
        print(f"resumed from step {start}")
    loader = ShardedLoader(src, start_step=start)
    state, hist = trainer.run(state, iter(loader), steps=args.steps - start,
                              log_every=10)
    print(f"done at step {int(state.step)}; loss {hist[0]:.3f} -> {hist[-1]:.3f}; "
          f"stragglers observed: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
