"""Policy lab: author a DRAM scheduling policy in ~20 lines, cost it,
sweep it against the built-ins, fan 256 candidate policies through ONE
compiled dispatch, and autotune a schedule that beats FR-FCFS — end to
end through the batched Campaign machinery.

EasyDRAM's first key idea is that scheduling policies are *software* on
a programmable memory controller. Here that is literal: a policy is a
:class:`repro.core.smcprog.PolicyProgram` — a dense int32 instruction
table a branchless VM interprets inside the emulator's scan — and its
SMC decision cost is derived from its length. Since PR 10 the table is
also a *runtime operand*: programs sharing a table-length bucket share
one compiled executable, and a vmapped policy axis evaluates a whole
candidate population per device dispatch — which is what makes the
closing autotuning demo (``core.policysearch``) affordable.

  PYTHONPATH=src python examples/policy_lab.py
"""
# before any repro.core import: emulator.py creates a device constant at
# import time, which initializes the CPU backend and locks the runtime
# (enable_fast_cpu_scan raises if called too late)
from repro.utils.jax_compat import enable_fast_cpu_scan

enable_fast_cpu_scan()

import numpy as np

from repro.core import emulator, smcprog
from repro.core.campaign import Campaign
from repro.core.emulator import Trace
from repro.core.policysearch import random_program, search
from repro.core.smcprog import PolicyBuilder
from repro.core.timescale import JETSON_NANO


def make_trace(n=2400, seed=7):
    """Bursty multi-bank traffic: 8-deep request bursts, 60% to one hot
    row — enough visible requests per decision that policy choice
    matters."""
    rng = np.random.RandomState(seed)
    delta = np.where(np.arange(n) % 8 == 0, 400, 0)
    row = np.where(rng.rand(n) < 0.6, 7, rng.randint(0, 4096, n))
    return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 4, n),
                    row=row, delta=delta)


def custom_policy():
    """A custom policy in ~20 lines: serve oldest first, prefer row
    hits on idle banks, and drain writes in batches of three — the kind
    of policy that needs RTL surgery on a hardware MC and is a page of
    Python here."""
    b = PolicyBuilder()
    age = b.score_age()
    hit = b.score_row_hit()
    busy = b.mask_bank_busy()
    drain = b.prefer_writes_drain(threshold=3)
    # boost class: row hits on idle banks, or writes during drain mode
    boost = b.or_(b.and_(hit, b.not_(busy)), drain)
    # penalize touching a busy bank by 32 cycles of effective age
    score = b.add(age, b.mul(busy, b.const(32)))
    return b.build(score=score, boost=boost, name="lab-custom")


def costed_sweep(tr):
    prog = custom_policy()
    print("=== custom policy, costed ===")
    print(prog.describe())

    grid = list(smcprog.builtin_programs().values()) + [prog]
    c = Campaign()
    for mode in ("ts", "nots"):
        # each program's SMC decision cost derives from its length —
        # the slowness ts hides. lab-custom (14 ops) packs to table
        # bucket 16 while the built-ins share bucket 8, and the policy
        # axis refuses to mix buckets silently — so this heterogeneous
        # grid takes the staged per-program path explicitly
        c.add_policy_grid(tr, JETSON_NANO, grid, mode=mode,
                          mode_label=mode, policy_axis=False)
    print(f"\n{len(c)} points in {c.n_groups()} compile groups "
          f"(one batched dispatch each)")
    recs = {(r["mode_label"], r["policy"]): r for r in c.run()}

    print(f"\n{'policy':>12s} {'smc_cyc':>8s} {'ts_cycles':>10s} "
          f"{'nots_cycles':>12s} {'row_hits':>8s}")
    for p in grid:
        ts, nots = recs[("ts", p.name)], recs[("nots", p.name)]
        print(f"{p.name:>12s} {p.smc_cycles():>8d} "
              f"{int(ts['exec_cycles']):>10d} "
              f"{int(nots['exec_cycles']):>12d} {int(ts['row_hits']):>8d}")
    print("\nts results ignore program length (time scaling hides SMC "
          "slowness);\nnots results grow with it — the ~20x modeling gap "
          "the paper quantifies.")


def policy_axis_sweep(tr, n_policies=256):
    """256 candidate policies through ONE executable: the runtime
    policy operand means table CONTENT is data, only the table-length
    bucket rides the compile key."""
    print(f"\n=== {n_policies}-policy sweep, one dispatch ===")
    rng = np.random.RandomState(0)
    progs = [random_program(rng, name=f"cand{i}")
             for i in range(n_policies - 1)]
    progs.append(smcprog.frfcfs_program())
    emulator.cache_clear()
    recs = emulator.run_policies(tr, JETSON_NANO, progs, mode="ts")
    stats = emulator.cache_stats()
    lat = [float(r["avg_load_latency_cycles"]) for r in recs]
    best = int(np.argmin(lat))
    print(f"{len(progs)} policies -> {stats['misses']} XLA compile(s); "
          f"best {progs[best].name} at {lat[best]:.2f} avg load-latency "
          f"cycles (frfcfs: {lat[-1]:.2f})")


def write_heavy_trace(n=360, seed=7):
    """Write-heavy traffic with hard bank conflicts (4 banks, small row
    space) — a workload where oldest-first row-hit scheduling is NOT
    optimal, so the search has real room over frfcfs."""
    rng = np.random.RandomState(seed)
    return Trace.of(kind=(rng.random_sample(n) < 0.6).astype(np.int32),
                    bank=rng.randint(0, 4, n), row=rng.randint(0, 64, n),
                    delta=rng.randint(1, 4, n),
                    dep=(rng.random_sample(n) < 0.3).astype(np.int32))


def autotune():
    """Evolutionary search over the op space; every generation scores
    its candidates with one vmapped dispatch."""
    print("\n=== autotune vs frfcfs (write-heavy workload) ===")
    res = search(write_heavy_trace(), JETSON_NANO,
                 generations=5, population=16, seed=0)
    print(res.summary())
    print(f"best-vs-baseline improvement: x{res.improvement:.4f}")
    print("\nwinning schedule:")
    print(res.best.describe())


def main():
    tr = make_trace()
    costed_sweep(tr)
    policy_axis_sweep(tr)
    autotune()


if __name__ == "__main__":
    main()
