"""Policy lab: author a DRAM scheduling policy in ~20 lines, cost it,
and sweep it against the built-ins — end to end through the batched
Campaign machinery.

EasyDRAM's first key idea is that scheduling policies are *software* on
a programmable memory controller. Here that is literal: a policy is a
:class:`repro.core.smcprog.PolicyProgram` — a dense int32 instruction
table a branchless VM interprets inside the emulator's scan — and its
SMC decision cost is derived from its length. The sweep below runs every
policy in both evaluation modes and prints the paper's point directly:

* ``ts``   (time scaling ON) — results are invariant to each program's
  cost: the emulated system sees the *modeled* MC, however slow the
  SMC software actually is.
* ``nots`` (PiDRAM-style) — the free-running system eats every SMC
  cycle, so longer policy programs visibly slow the same workload.

  PYTHONPATH=src python examples/policy_lab.py
"""
# before any repro.core import: emulator.py creates a device constant at
# import time, which initializes the CPU backend and locks the runtime
# (enable_fast_cpu_scan raises if called too late)
from repro.utils.jax_compat import enable_fast_cpu_scan

enable_fast_cpu_scan()

import numpy as np

from repro.core import smcprog
from repro.core.campaign import Campaign
from repro.core.emulator import Trace
from repro.core.smcprog import PolicyBuilder
from repro.core.timescale import JETSON_NANO


def make_trace(n=2400, seed=7):
    """Bursty multi-bank traffic: 8-deep request bursts, 60% to one hot
    row — enough visible requests per decision that policy choice
    matters."""
    rng = np.random.RandomState(seed)
    delta = np.where(np.arange(n) % 8 == 0, 400, 0)
    row = np.where(rng.rand(n) < 0.6, 7, rng.randint(0, 4096, n))
    return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 4, n),
                    row=row, delta=delta)


def custom_policy():
    """A custom policy in ~20 lines: serve oldest first, prefer row
    hits on idle banks, and drain writes in batches of three — the kind
    of policy that needs RTL surgery on a hardware MC and is a page of
    Python here."""
    b = PolicyBuilder()
    age = b.score_age()
    hit = b.score_row_hit()
    busy = b.mask_bank_busy()
    drain = b.prefer_writes_drain(threshold=3)
    # boost class: row hits on idle banks, or writes during drain mode
    boost = b.or_(b.and_(hit, b.not_(busy)), drain)
    # penalize touching a busy bank by 32 cycles of effective age
    score = b.add(age, b.mul(busy, b.const(32)))
    return b.build(score=score, boost=boost, name="lab-custom")


def main():
    prog = custom_policy()
    print("=== custom policy, costed ===")
    print(prog.describe())

    grid = list(smcprog.builtin_programs().values()) + [prog]
    tr = make_trace()
    base = JETSON_NANO
    c = Campaign()
    for mode in ("ts", "nots"):
        # with_policy (inside add_policy_grid) derives each program's
        # SMC decision cost from its length — the slowness ts hides
        c.add_policy_grid(tr, base, grid, mode=mode, mode_label=mode)
    print(f"\n{len(c)} points in {c.n_groups()} compile groups "
          f"(one batched dispatch each)")
    recs = {(r["mode_label"], r["policy"]): r for r in c.run()}

    print(f"\n{'policy':>12s} {'smc_cyc':>8s} {'ts_cycles':>10s} "
          f"{'nots_cycles':>12s} {'row_hits':>8s}")
    for p in grid:
        ts, nots = recs[("ts", p.name)], recs[("nots", p.name)]
        print(f"{p.name:>12s} {p.smc_cycles():>8d} "
              f"{int(ts['exec_cycles']):>10d} "
              f"{int(nots['exec_cycles']):>12d} {int(ts['row_hits']):>8d}")
    print("\nts results ignore program length (time scaling hides SMC "
          "slowness);\nnots results grow with it — the ~20x modeling gap "
          "the paper quantifies.")


if __name__ == "__main__":
    main()
