"""Two sweep clients sharing one warm engine (ISSUE 9).

Run:  PYTHONPATH=src python examples/sweep_service.py

Two tenant threads — a "rowhammer" study and a "scheduling" study —
drive the SAME `SweepServer`. Both sweep the same polybench traces, so
their points land in the same campaign groups and the server coalesces
them into shared batched dispatches: the engine compiles each
executable once and each device dispatch retires points for BOTH
clients. The printed `coalesce_ratio` (mean distinct clients per
dispatch) shows the cross-client sharing; results are bit-identical to
each client running its own `Campaign`.

For separate processes, start the server standalone

    PYTHONPATH=src python -m repro.service --port 7421

and attach with ``SweepClient(address=("127.0.0.1", 7421))`` instead
of ``SweepClient(server=srv)`` — same API, same results.
"""
import threading

from repro.core import traces
from repro.core.faults import FaultModel
from repro.core.smcprog import mitigation_programs
from repro.core.timescale import JETSON_NANO
from repro.service import SweepClient, SweepServer

GEO = JETSON_NANO.geometry
WORKLOADS = traces.POLYBENCH[:4]


def hammer_study(srv, out):
    """Tenant A: fault impact per workload — a fault-free baseline
    point plus a RowHammer-prone arm. The baseline points use the same
    (system, mode, length-bucket) group as tenant B's baselines, so
    the server coalesces the two tenants' baselines into shared
    dispatches."""
    fm = FaultModel(seed=7, hammer_threshold=16, hammer_flip_fp=52000)
    cli = SweepClient(server=srv, name="hammer", weight=1.0)
    for w in WORKLOADS:
        tr, _ = traces.polybench_trace(w, GEO, max_accesses=800, seed=0)
        cli.submit(tr, JETSON_NANO, mode="ts", workload=w.name,
                   arm="baseline")
        cli.submit(tr, JETSON_NANO.with_faults(fm), mode="ts",
                   workload=w.name, arm="faults")
    out["hammer"] = cli.collect()


def policy_study(srv, out):
    """Tenant B: TRR mitigation cost — the same baseline grid as
    tenant A (coalesced with it) plus a TRR-policy arm."""
    trr = mitigation_programs(trr_threshold=16)["trr16"]
    cli = SweepClient(server=srv, name="policy", weight=1.0)
    for w in WORKLOADS:
        tr, _ = traces.polybench_trace(w, GEO, max_accesses=800, seed=0)
        cli.submit(tr, JETSON_NANO, mode="ts", workload=w.name,
                   arm="baseline")
        cli.submit(tr, JETSON_NANO.with_policy(trr), mode="ts",
                   workload=w.name, arm="trr16")
    out["policy"] = cli.collect()


def main():
    out = {}
    with SweepServer(coalesce_window_s=0.05) as srv:
        threads = [threading.Thread(target=hammer_study, args=(srv, out)),
                   threading.Thread(target=policy_study, args=(srv, out))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = srv.stats()

    print("tenant A (fault impact):")
    for r in out["hammer"]:
        if r["arm"] == "faults":
            print(f"  {r['workload']:<12} flips={int(r['flips'])} "
                  f"(BER {float(r['bit_error_rate']):.5f})")
    print("tenant B (TRR mitigation cost):")
    base = {r["workload"]: r for r in out["policy"] if r["arm"] == "baseline"}
    for r in out["policy"]:
        if r["arm"] == "trr16":
            slow = int(r["exec_cycles"]) / int(base[r["workload"]]
                                               ["exec_cycles"])
            print(f"  {r['workload']:<12} {slow:.3f}x cycles")
    d = st["dispatches"]
    print(f"\nserver: {d['points']} points in {d['count']} dispatches "
          f"({st['points_per_dispatch']:.1f} points/dispatch), "
          f"coalesce_ratio={st['coalesce_ratio']:.2f} "
          f"(>1.0 means dispatches served BOTH tenants), "
          f"compile misses={st['compile']['misses']}, "
          f"p50 latency {st['latency_ms']['p50']:.1f} ms")


if __name__ == "__main__":
    main()
