"""Case study (paper Sec. 7): RowClone end-to-end, with and without time
scaling — reproduces the paper's core finding that platforms that do not
faithfully model a modern CPU inflate DRAM-technique benefits.

  PYTHONPATH=src python examples/rowclone_case_study.py

Second runs start fast: XLA executables persist in artifacts/xla_cache
(enable_persistent_compile_cache below), so a fresh process skips the
cold compiles, and the size sweeps execute through the overlapped
campaign executor.
"""
import warnings

warnings.filterwarnings("ignore")

# both must precede the first jax computation (backend init)
from repro.utils.jax_compat import (enable_fast_cpu_scan,
                                    enable_persistent_compile_cache)

enable_fast_cpu_scan()
enable_persistent_compile_cache()

from repro.core.dram import Geometry
from repro.core.profiling import DeviceModel
from repro.core.techniques import RowClone
from repro.core.timescale import JETSON_NANO, PIDRAM_LIKE

TS_LINE = 4     # A57-class copy loop (cycles per 64B line)
NOTS_LINE = 20  # 50 MHz in-order rv64 copy loop


def main():
    dev = DeviceModel(Geometry())
    rc_ts = RowClone(JETSON_NANO, dev)        # EasyDRAM - Time Scaling
    rc_nots = RowClone(PIDRAM_LIKE, dev)      # PiDRAM-like - No Time Scaling

    sizes = (65536, 1 << 20, 4 << 20)
    for setting in ("noflush", "clflush"):
        print(f"\n=== Copy, {setting} (speedup over CPU ld/st copy) ===")
        print(f"{'size':>10s} {'TS':>8s} {'NoTS':>8s} {'inflation':>10s}")
        # the whole size sweep runs as one batched campaign per system
        # (emulator.run_many under the hood: one compile per group)
        a_all = rc_ts.evaluate_batch(sizes, "copy", setting, "ts",
                                     cpu_line_delta=TS_LINE)
        b_all = rc_nots.evaluate_batch(sizes, "copy", setting, "nots",
                                       cpu_line_delta=NOTS_LINE)
        for nb, a, b in zip(sizes, a_all, b_all):
            s_ts = a["rowclone"].speedup_vs_cpu
            s_no = b["rowclone"].speedup_vs_cpu
            print(f"{nb:>10d} {s_ts:>7.1f}x {s_no:>7.1f}x {s_no/s_ts:>9.1f}x")
    print("\npaper: TS 15.0x vs NoTS 306.7x avg (copy, no-flush) -> ~20x "
          "inflation from not modeling the real CPU")


if __name__ == "__main__":
    main()
