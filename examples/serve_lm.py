"""Serving driver: batched prefill + decode with KV cache, plus the
RowClone-analog KV-page fork, and the DRAM-level cost of the same fork
evaluated by the EasyDRAM engine (framework <-> paper tie-in).

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6_3b
"""
import argparse
import time
import warnings

warnings.filterwarnings("ignore")

# before any repro.core import: emulator.py creates a device constant at
# import time, which initializes the CPU backend and locks the runtime
from repro.utils.jax_compat import enable_fast_cpu_scan

enable_fast_cpu_scan()

import jax
import numpy as np

from repro.configs import SSMConfig, get_config
from repro.core import emulator, traces
from repro.core.dram import Geometry
from repro.core.profiling import DeviceModel
from repro.core.timescale import JETSON_NANO
from repro.models import model_zoo
from repro.serve.engine import ServeEngine

REDUCE = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
              vocab_size=512, head_dim=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    over = dict(REDUCE)
    cfg0 = get_config(args.arch)
    if cfg0.attn_free:
        over["n_kv_heads"] = over["n_heads"]
        over["ssm"] = SSMConfig(chunk=16)
    cfg = cfg0.scaled(**over)
    model = model_zoo.build(cfg, s_max=64)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, s_max=64)

    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(args.batch, 16))
    t0 = time.perf_counter()
    outs = engine.generate_batch(prompts, args.new)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch} reqs x {args.new} tokens "
          f"in {dt:.2f}s ({args.batch*args.new/dt:.1f} tok/s)")
    print("first continuation:", outs[0].tolist())

    # KV page fork: on-TPU analogue (Pallas copy kernel path)...
    _, cache = model.prefill_fn(params, {"tokens": prompts[:1]})
    forked = engine.fork_cache(cache, 4, use_kernel=True)
    print("forked cache x4:",
          jax.tree_util.tree_leaves(forked)[0].shape)

    # ...and the same fork's DRAM cost under the EasyDRAM engine — both
    # arms batched through one run_many campaign step
    dev = DeviceModel(Geometry())
    tr_rc, _ = traces.kv_fork_trace(16, 8192, Geometry(), "rowclone", dev)
    tr_cpu, _ = traces.kv_fork_trace(16, 8192, Geometry(), "cpu", dev)
    a, b = emulator.run_many([tr_cpu, tr_rc], JETSON_NANO, "ts")
    print(f"DRAM-level fork (16 pages): cpu={int(a['exec_cycles'])} cyc, "
          f"rowclone={int(b['exec_cycles'])} cyc "
          f"({int(a['exec_cycles'])/max(int(b['exec_cycles']),1):.1f}x)")


if __name__ == "__main__":
    main()
