"""Quickstart: train a tiny GQA LM on synthetic data, checkpoint, serve
a few greedy tokens, then run a batched DRAM-emulation campaign — the
whole public API in ~60 lines.

The emulation side has three entry points: ``emulator.run`` for one
(trace, system, mode) point, ``emulator.run_many`` /
``campaign.Campaign`` for sweeps — a Campaign collects grid points,
groups them by compile key (trace bucket, SystemConfig, mode, Bloom
shape), and executes each group as one vmapped jit call, so a sweep
compiles once per group instead of once per point — and
``emulator.run_stream`` / ``run_stream_many`` for unbounded traces,
which scan constant-memory windows through one length-independent
executable and stay bit-identical to single-shot.

  PYTHONPATH=src python examples/quickstart.py
"""
# before any repro.core import: emulator.py creates a device constant at
# import time, which initializes the CPU backend and locks the runtime
from repro.utils.jax_compat import enable_fast_cpu_scan

enable_fast_cpu_scan()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import traces
from repro.core.campaign import Campaign
from repro.core.dram import Geometry
from repro.core.timescale import JETSON_NANO
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.models import model_zoo
from repro.serve.engine import ServeEngine
from repro.train import optimizer as opt
from repro.train.trainer import Trainer


def main():
    cfg = get_config("qwen3_8b").scaled(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, head_dim=32)
    model = model_zoo.build(cfg, s_max=64)
    print(f"arch={cfg.name} (reduced) params={model.n_params():,}")

    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=16, seed=0)
    trainer = Trainer(model, opt.AdamWConfig(lr=1e-2, warmup=10, total_steps=300),
                      ckpt_dir="/tmp/repro_quickstart", ckpt_every=50)
    state, restored = trainer.restore_or_init()
    print("restored from checkpoint" if restored else "fresh init")
    state, hist = trainer.run(state, iter(ShardedLoader(src)), steps=60,
                              log_every=20)
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f}")

    params = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), state.master)
    engine = ServeEngine(model, params, s_max=64)
    prompt = np.asarray(src.batch(0)["tokens"])[0, :16]
    out = engine.generate(prompt, max_new=16)
    print("generated:", out)

    # batched emulation campaign: sweep PolyBench kernels x {ts, nots}
    # in grouped vmapped calls (one compile per group, not per point)
    geo = Geometry()
    camp = Campaign()
    for i, kern in enumerate(traces.POLYBENCH[:3]):
        tr, _ = traces.polybench_trace(kern, geo, max_accesses=2000, seed=i)
        if tr is None:
            continue
        for mode in ("ts", "nots"):
            camp.add(tr, JETSON_NANO, mode=mode, kernel=kern.name)
    print(f"\ncampaign: {len(camp)} points in {camp.n_groups()} compile groups")
    for r in camp.run():
        print(f"  {r['kernel']:>10s} {r['mode']:>4s}: "
              f"{int(r['exec_cycles']):>9d} cycles")

    # unbounded traces stream through constant-memory windows: the
    # generator below never materializes its 50k requests, the compiled
    # window executable is length-independent (one compile key for any
    # trace length), and the result is bit-identical to single-shot run
    from repro.core.emulator import run_stream
    stream = traces.synthetic_stream(50_000, window=4096, seed=7)
    r = run_stream(stream, JETSON_NANO, "ts", collect="aggregate")
    print(f"\nstreamed {int(r['n_requests']):,} requests: "
          f"{int(r['exec_cycles']):,} cycles, "
          f"avg load latency {r['avg_load_latency_cycles']:.1f} cycles")

    # scheduling policies are software too (see examples/policy_lab.py
    # for the full lab): author one, cost it, run it
    from repro.core.smcprog import PolicyBuilder
    b = PolicyBuilder()
    prog = b.build(score=b.score_age(), boost=b.score_row_hit(),
                   name="my-frfcfs")
    tr, _ = traces.polybench_trace(traces.POLYBENCH[0], geo,
                                   max_accesses=2000, seed=0)
    from repro.core.emulator import run
    r = run(tr, JETSON_NANO.with_policy(prog), "ts")
    print(f"\npolicy {prog.name} ({prog.smc_cycles()} smc-cycles/decision): "
          f"{int(r['exec_cycles'])} cycles")

    # deterministic fault injection (PR 8): attach a FaultModel and the
    # engine reports bit flips — RowHammer disturbance + retention
    # failures — reproducibly (same seed => same flip set, across every
    # engine). Mitigations are policy programs: counter-based TRR below
    # suppresses the flips at a small neighbor-refresh slowdown cost.
    from repro.core.faults import FaultModel
    from repro.core.smcprog import mitigation_programs
    fm = FaultModel(seed=7, hammer_threshold=32, hammer_flip_fp=52000)
    storm = traces.rowhammer_trace(2000, geo, intensity=0.85, seed=1)
    plain = run(storm, JETSON_NANO.with_faults(fm), "ts")
    trr = mitigation_programs(trr_threshold=16)["trr16"]
    guarded = run(storm, JETSON_NANO.with_policy(trr).with_faults(fm), "ts")
    print(f"\nrowhammer storm unmitigated: {int(plain['flips'])} flips "
          f"(BER {float(plain['bit_error_rate']):.4f})")
    print(f"with TRR policy: {int(guarded['flips'])} flips, "
          f"{int(guarded['mitigations'])} neighbor refreshes, "
          f"{int(guarded['exec_cycles']) / int(plain['exec_cycles']):.3f}x "
          f"cycles")

    # shared sweep server (ISSUE 9): many clients, one warm engine —
    # compatible points from different clients coalesce into shared
    # batched dispatches, bit-identical to a direct Campaign.run (see
    # examples/sweep_service.py for the full two-client walkthrough)
    from repro.service import SweepClient, SweepServer
    with SweepServer() as srv:
        cli = SweepClient(server=srv, name="quickstart")
        for i in range(3):
            t, _ = traces.polybench_trace(traces.POLYBENCH[i], geo,
                                          max_accesses=500, seed=i)
            cli.submit(t, JETSON_NANO, mode="ts", workload=i)
        recs = cli.collect()
        st = srv.stats()
    print(f"\nsweep service: {len(recs)} points in "
          f"{st['dispatches']['count']} dispatch(es), "
          f"p50 latency {st['latency_ms']['p50']:.1f} ms")


if __name__ == "__main__":
    main()
