"""Sweep service (ISSUE 9): multi-client bit-identity, cross-client
coalescing, weighted fairness, typed backpressure, socket transport,
drain/abort shutdown with resumable checkpoints, and the two
concurrency fixes that ride along (consistent ``cache_stats``
snapshots, executor atexit poisoning)."""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import emulator, executor
from repro.core.bloom import BloomFilter
from repro.core.campaign import Campaign, Point
from repro.core.emulator import Trace
from repro.core.faults import FaultModel
from repro.core.smcprog import frfcfs_program
from repro.core.timescale import JETSON_NANO
from repro.service import (QueueFullError, ServerClosedError, SweepClient,
                           SweepServer, load_pending)

SYS_FAULTS = JETSON_NANO.with_faults(
    FaultModel(seed=3, hammer_threshold=8, hammer_flip_fp=30000,
               weak_fp=16000, retention_ticks=30, victim_slots=16))
SYS_POLICY = JETSON_NANO.with_policy(frfcfs_program())


def mk_traces(n_traces, base=56, step=9, seed=11):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n_traces):
        n = base + step * i
        out.append(Trace.of(kind=rng.randint(0, 2, n),
                            bank=rng.randint(0, 16, n),
                            row=rng.randint(0, 4096, n),
                            delta=rng.randint(1, 8, n),
                            dep=rng.randint(0, 2, n)))
    return out


def small_bloom(seed=0):
    rng = np.random.RandomState(seed)
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 150).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    return (bf.bits, bf.k, bf.m_bits)


def mixed_points(n_base=5, seed=11):
    """A grid mixing modes, fault/policy systems, and a bloom arm —
    every group-key dimension the coalescer must keep separate."""
    trs = mk_traces(n_base, seed=seed)
    bloom = small_bloom()
    pts = []
    for i, tr in enumerate(trs):
        pts.append(Point(tr, JETSON_NANO, "ts", None, {"idx": len(pts)}))
        pts.append(Point(tr, JETSON_NANO, "nots", None, {"idx": len(pts)}))
        if i % 2 == 0:
            pts.append(Point(tr, SYS_FAULTS, "ts", None, {"idx": len(pts)}))
            pts.append(Point(tr, JETSON_NANO, "ts", bloom,
                             {"idx": len(pts)}))
        else:
            pts.append(Point(tr, SYS_POLICY, "ts", None, {"idx": len(pts)}))
    return pts


def serial_reference(pts):
    c = Campaign()
    for p in pts:
        c.add(p.trace, p.sys, mode=p.mode, bloom=p.bloom, **p.meta)
    return c.run(serial=True)


def assert_same_record(a, b):
    assert int(a["exec_cycles"]) == int(b["exec_cycles"])
    np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
    np.testing.assert_array_equal(a["t_issue"], b["t_issue"])


class TestBitIdentity:
    def test_three_clients_mixed_grid_matches_serial_campaign(self):
        """K concurrent clients submitting an interleaved mixed grid
        (ts/nots x plain/fault/policy/bloom) get records bit-identical
        to one serial Campaign over the same points."""
        pts = mixed_points()
        ref = serial_reference(pts)
        got = {}
        errs = []
        with SweepServer(coalesce_window_s=0.05) as srv:
            def client(k):
                try:
                    cli = SweepClient(server=srv, name=f"c{k}")
                    cli.submit_points([p for j, p in enumerate(pts)
                                       if j % 3 == k])
                    for r in cli.collect():
                        got[r["idx"]] = r
                except BaseException as e:   # pragma: no cover
                    errs.append(e)
            threads = [threading.Thread(target=client, args=(k,))
                       for k in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(180)
            st = srv.stats()
        assert not errs, errs
        assert len(got) == len(ref)
        for i, r in enumerate(ref):
            assert_same_record(got[i], r)
        assert st["dispatches"]["points"] == len(pts)
        assert st["rejected"] == 0

    def test_coalesces_across_clients(self):
        """Same-group points from different clients share dispatches:
        the mean distinct-clients-per-dispatch exceeds 1."""
        tr = mk_traces(1, base=64)[0]
        with SweepServer(coalesce_window_s=0.25) as srv:
            clis = [SweepClient(server=srv, name=f"c{k}") for k in range(3)]
            for k, cli in enumerate(clis):
                cli.submit_points([Point(tr, JETSON_NANO, "ts", None,
                                         {"k": k, "j": j})
                                   for j in range(4)])
            recs = [cli.collect() for cli in clis]
            st = srv.stats()
        assert st["dispatches"]["count"] == 1
        assert st["coalesce_ratio"] == 3.0
        assert st["points_per_dispatch"] == 12.0
        base = recs[0][0]
        for rs in recs:
            assert len(rs) == 4
            for r in rs:
                assert_same_record(r, base)

    def test_collect_preserves_submission_order(self):
        pts = mixed_points(3, seed=4)
        ref = serial_reference(pts)
        with SweepServer(coalesce_window_s=0.02) as srv:
            cli = SweepClient(server=srv, name="solo")
            cli.submit_points(pts)
            out = cli.collect()
        assert [r["idx"] for r in out] == [r["idx"] for r in ref]
        for a, b in zip(out, ref):
            assert_same_record(a, b)


class TestBackpressure:
    def test_per_client_bound_is_typed_and_atomic(self):
        trs = mk_traces(4, base=48, step=0)
        with SweepServer(max_pending=2, coalesce_window_s=30.0,
                         max_batch=512) as srv:
            cli = SweepClient(server=srv, name="hog")
            with pytest.raises(QueueFullError) as ei:
                cli.submit_points([Point(t, JETSON_NANO, "ts") for t in trs])
            assert ei.value.scope == "per-client"
            assert ei.value.bound == 2 and ei.value.requested == 4
            # all-or-nothing: nothing from the rejected batch is queued
            assert srv.stats()["clients"]["hog"]["queue_depth"] == 0
            assert srv.stats()["clients"]["hog"]["rejected"] == 4
            cli.submit_points([Point(t, JETSON_NANO, "ts")
                               for t in trs[:2]])  # now fits
            srv.close(drain=True)
            assert len(cli.collect()) == 2

    def test_global_bound_names_the_global_scope(self):
        trs = mk_traces(3, base=48, step=0)
        with SweepServer(max_pending=8, max_queue=2, max_batch=512,
                         coalesce_window_s=30.0) as srv:
            a = SweepClient(server=srv, name="a")
            b = SweepClient(server=srv, name="b")
            a.submit_points([Point(trs[0], JETSON_NANO, "ts"),
                             Point(trs[1], JETSON_NANO, "ts")])
            with pytest.raises(QueueFullError) as ei:
                b.submit(trs[2], JETSON_NANO)
            assert ei.value.scope == "global"
            srv.close(drain=True)
            assert len(a.collect()) == 2

    def test_closed_server_raises_typed(self):
        tr = mk_traces(1)[0]
        srv = SweepServer()
        cli = SweepClient(server=srv, name="late")
        srv.close()
        with pytest.raises(ServerClosedError):
            cli.submit(tr, JETSON_NANO)
        with pytest.raises(ServerClosedError):
            SweepClient(server=srv, name="later")

    def test_stream_points_rejected_typed(self):
        with SweepServer() as srv:
            cli = SweepClient(server=srv, name="s")
            with pytest.raises(ValueError, match="stream"):
                cli.submit_points([Point(mk_traces(1)[0], JETSON_NANO,
                                         "ts", stream=True)])


class TestFairness:
    def test_stride_order_gives_weighted_share(self):
        """With A at weight 1 and B at weight 2 queued together, the
        dispatcher's stride drain interleaves them 1:2 — B holds two of
        every three leading slots (first six: A,B,B,A,B,B)."""
        tr = mk_traces(1, base=64)[0]
        srv = SweepServer(coalesce_window_s=30.0, max_batch=512)
        try:
            a = SweepClient(server=srv, name="a", weight=1.0)
            b = SweepClient(server=srv, name="b", weight=2.0)
            # the server condition uses an RLock: holding it here keeps
            # the dispatcher from draining until BOTH batches are queued
            with srv._cond:
                a.submit_points([Point(tr, JETSON_NANO, "ts", None,
                                       {"c": "a", "j": j})
                                 for j in range(4)])
                b.submit_points([Point(tr, JETSON_NANO, "ts", None,
                                       {"c": "b", "j": j})
                                 for j in range(4)])
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                with srv._cond:
                    jobs = [j for bk in srv._buckets.values()
                            for j in bk.jobs]
                if len(jobs) == 8:
                    break
                time.sleep(0.01)
            order = [j.client for j in jobs]
            assert order[:6] == ["a", "b", "b", "a", "b", "b"], order
            srv.close(drain=True)
            assert len(a.collect()) == 4 and len(b.collect()) == 4
        finally:
            srv.close(drain=False)


class TestSocket:
    def test_roundtrip_stats_and_typed_errors(self):
        pts = mixed_points(3, seed=9)
        ref = serial_reference(pts)
        with SweepServer(coalesce_window_s=0.02, max_pending=64) as srv:
            host, port = srv.listen()
            with SweepClient(address=(host, port), name="far") as cli:
                assert cli.name == "far"
                cli.submit_points(pts)
                out = cli.collect()
                for a, b in zip(out, ref):
                    assert_same_record(a, b)
                st = cli.stats()
                assert st["clients"]["far"]["completed"] == len(pts)
            # typed backpressure crosses the wire with fields intact
            with SweepServer(max_pending=1, coalesce_window_s=30.0) as tiny:
                h2, p2 = tiny.listen()
                with SweepClient(address=(h2, p2), name="far2") as cli2:
                    with pytest.raises(QueueFullError) as ei:
                        cli2.submit_points(
                            [Point(pts[0].trace, JETSON_NANO, "ts"),
                             Point(pts[1].trace, JETSON_NANO, "ts")])
                    assert ei.value.scope == "per-client"
                    assert ei.value.bound == 1


class TestCheckpoint:
    def test_drain_close_leaves_loadable_group_checkpoints(self, tmp_path):
        d = str(tmp_path)
        pts = mixed_points(3, seed=6)
        with SweepServer(checkpoint=d, coalesce_window_s=0.02) as srv:
            cli = SweepClient(server=srv, name="a")
            cli.submit_points(pts)
            first = cli.collect()
        assert any(f.startswith("group-") for f in os.listdir(d))
        # a fresh server serves the identical grid from disk: zero
        # executor dispatches, bit-identical records
        with SweepServer(checkpoint=d, coalesce_window_s=0.02) as srv:
            cli = SweepClient(server=srv, name="b")
            cli.submit_points(pts)
            again = cli.collect()
            st = srv.stats()
        assert st["dispatches"]["loaded_from_checkpoint"] \
            == st["dispatches"]["count"] > 0
        for a, b in zip(first, again):
            assert_same_record(a, b)

    def test_abort_close_pends_unfinished_and_resumes(self, tmp_path):
        """close(drain=False) fails queued points with a typed error
        naming the manifest dir; Campaign.run(checkpoint=dir) then
        finishes the sweep bit-identically, loading finished groups."""
        d = str(tmp_path)
        pts = mixed_points(4, seed=8)
        half, rest = pts[: len(pts) // 2], pts[len(pts) // 2:]
        with SweepServer(checkpoint=d, coalesce_window_s=0.02) as srv:
            cli = SweepClient(server=srv, name="a")
            cli.submit_points(half)
            cli.collect()
        srv = SweepServer(checkpoint=d, coalesce_window_s=30.0,
                          max_batch=512)
        cli = SweepClient(server=srv, name="a")
        cli.submit_points(rest)
        srv.close(drain=False)
        with pytest.raises(ServerClosedError) as ei:
            cli.collect()
        assert ei.value.checkpoint == d
        pend = load_pending(d)
        assert [p.meta["idx"] for p in pend] == [p.meta["idx"] for p in rest]
        c = Campaign()
        for p in half + pend:
            c.add(p.trace, p.sys, mode=p.mode, bloom=p.bloom, **p.meta)
        resumed = c.run(checkpoint=d)
        ref = serial_reference(pts)
        assert len(resumed) == len(ref)
        for a, b in zip(resumed, ref):
            assert_same_record(a, b)


class TestShutdownSafety:
    def test_interpreter_exit_without_close_does_not_hang(self):
        """A client process that never closes its server — including
        one with queued-but-undispatched points — must exit cleanly:
        the service atexit hook closes live servers before the executor
        pool poisons itself."""
        code = """
import numpy as np
from repro.core.emulator import Trace
from repro.core.timescale import JETSON_NANO
from repro.service import SweepServer, SweepClient
rng = np.random.RandomState(0)
def mk():
    return Trace.of(kind=rng.randint(0, 2, 48), bank=rng.randint(0, 16, 48),
                    row=rng.randint(0, 4096, 48), delta=rng.randint(1, 8, 48),
                    dep=rng.randint(0, 2, 48))
srv = SweepServer(coalesce_window_s=0.01)
cli = SweepClient(server=srv, name="x")
cli.submit(mk(), JETSON_NANO)
assert cli.collect()[0]["exec_cycles"] > 0
# second server: points queued behind a huge window, NEVER collected,
# NEVER closed -- exit must still be clean
srv2 = SweepServer(coalesce_window_s=3600.0)
cli2 = SweepClient(server=srv2, name="y")
cli2.submit(mk(), JETSON_NANO)
print("EXITING")
"""
        env = dict(os.environ, PYTHONPATH="src")
        proc = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                              env=env, capture_output=True, text=True,
                              timeout=420)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "EXITING" in proc.stdout

    def test_executor_shutdown_poisons_then_set_workers_rearms(self):
        class Probe:
            retryable = False

            def __init__(self):
                self.ran = threading.Event()

            def run(self):
                self.ran.set()

        prev = executor.workers()
        try:
            executor.shutdown()
            assert executor.is_shutdown()
            with pytest.raises(RuntimeError, match="shut down"):
                executor.submit_task(Probe())
            executor.set_workers(prev)
            assert not executor.is_shutdown()
            p = Probe()
            assert executor.submit_task(p).result(30) is None  # no failure
            assert p.ran.is_set()
        finally:
            executor.set_workers(prev)


def test_cache_stats_consistent_under_threads():
    """Satellite 1: `cache_stats()` snapshots must be internally
    consistent (lookups == hits + misses, size <= capacity,
    size == misses - evictions between clears) even while worker
    threads drive lookups through the executable LRU."""
    trs = mk_traces(2, base=40, step=24, seed=2)
    stop = threading.Event()
    errs = []

    def reader():
        while not stop.is_set():
            s = emulator.cache_stats()
            try:
                assert s["lookups"] == s["hits"] + s["misses"]
                assert s["size"] <= s["capacity"]
                assert s["size"] == s["misses"] - s["evictions"]
            except AssertionError as e:   # pragma: no cover
                errs.append(e)
                stop.set()
                return

    def worker(tr):
        for _ in range(30):
            if stop.is_set():
                return
            emulator.run(tr, JETSON_NANO, "ts")

    threads = [threading.Thread(target=reader) for _ in range(2)] + \
        [threading.Thread(target=worker, args=(trs[i % 2],))
         for i in range(3)]
    for t in threads[2:]:
        t.start()
    for t in threads[:2]:
        t.start()
    for t in threads[2:]:
        t.join(300)
    stop.set()
    for t in threads[:2]:
        t.join(30)
    assert not errs, errs[0]
