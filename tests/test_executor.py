"""The overlapped campaign executor (PR 5): bit-identity of overlapped /
sharded execution vs the serial PR 4 group loop, add-order preservation,
the LRU bound on the in-memory executable cache, the persistent on-disk
compile cache across processes, and the ValueError API guards. PR 8
adds the fault-tolerance layer: per-task failure isolation with
aggregate errors, bounded retry + dispatch timeouts, the stream-prefetch
shutdown contract, and campaign checkpoint/resume (including a
kill-mid-campaign subprocess resume)."""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import emulator, executor, smcprog
from repro.core.bloom import BloomFilter
from repro.core.campaign import Campaign
from repro.core.emulator import Trace, run_many
from repro.core.timescale import JETSON_NANO


def mk_trace(rng, n):
    return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                    row=rng.randint(0, 4096, n), delta=rng.randint(1, 8, n),
                    dep=rng.randint(0, 2, n))


def small_bloom(seed=0):
    rng = np.random.RandomState(seed)
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 150).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    return (bf.bits, bf.k, bf.m_bits)


def mixed_grid_campaign(seed=3):
    """A heterogeneous grid spanning modes x policies x bloom arms x two
    length buckets — the shape the overlapped executor must keep
    bit-identical to the serial loop."""
    rng = np.random.RandomState(seed)
    trs = [mk_trace(rng, n) for n in (40, 44, 90, 95)]
    bloom = small_bloom(seed)
    prog = smcprog.frfcfs_program()
    c = Campaign()
    for i, tr in enumerate(trs):
        for mode in ("ts", "nots"):
            c.add(tr, JETSON_NANO, mode=mode, i=i, arm="plain")
        c.add(tr, JETSON_NANO, mode="ts", bloom=bloom, i=i, arm="bloom")
        c.add_policy_grid(tr, JETSON_NANO, [prog], mode="ts",
                          derive_cost=False, i=i, arm="policy")
    return c


class TestOverlapBitIdentity:
    def test_campaign_overlapped_matches_serial(self):
        c = mixed_grid_campaign()
        assert c.n_groups() >= 6  # genuinely heterogeneous
        a = c.run(serial=True)
        b = c.run()
        assert len(a) == len(b) == len(c)
        for x, y in zip(a, b):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            assert int(x["row_hits"]) == int(y["row_hits"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])
            np.testing.assert_array_equal(x["t_issue"], y["t_issue"])
            assert x["mode"] == y["mode"]

    def test_run_many_overlapped_matches_serial(self):
        rng = np.random.RandomState(11)
        trs = [mk_trace(rng, n) for n in (35, 70, 140, 40, 80)]
        modes = ["ts", "nots", "ts", "reference", "ts"]
        a = run_many(trs, JETSON_NANO, modes, serial=True)
        b = run_many(trs, JETSON_NANO, modes)
        for x, y in zip(a, b):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])

    def test_add_order_preserved(self):
        """Records come back in add order even though groups execute
        concurrently and finish in arbitrary order."""
        c = mixed_grid_campaign(seed=9)
        for j, p in enumerate(c.points):
            p.meta["seq"] = j
        recs = c.run()
        assert [r["seq"] for r in recs] == list(range(len(c)))
        # and per-point identity against the single-trace path
        k = len(c) // 2
        p = c.points[k]
        solo = emulator.run(p.trace, p.sys, p.mode, bloom=p.bloom)
        assert int(solo["exec_cycles"]) == int(recs[k]["exec_cycles"])

    def test_executor_propagates_worker_errors(self):
        def boom():
            raise RuntimeError("pack failed")
        tasks = [executor.GroupTask(fn=lambda: None, pack=boom,
                                    finalize=lambda o, c: None)
                 for _ in range(3)]
        with pytest.raises(RuntimeError, match="pack failed"):
            executor.execute(tasks, serial=False)

    def test_set_workers_validates_and_restores(self):
        old = executor.set_workers(1)
        try:
            # workers=1 forces the serial fallback; results unchanged
            rng = np.random.RandomState(2)
            trs = [mk_trace(rng, 40), mk_trace(rng, 90)]
            out = run_many(trs, JETSON_NANO, ["ts", "nots"])
            assert all(r is not None for r in out)
            with pytest.raises(ValueError, match="worker count"):
                executor.set_workers(0)
        finally:
            executor.set_workers(old)


class FakeTask:
    """Executor-contract probe: controllable failures, no XLA compiles."""
    retryable = True

    def __init__(self, label, fails=0, sleep=0.0):
        self.label, self.cost = label, 1
        self.fails, self.sleep, self.runs = fails, sleep, 0

    def run(self):
        self.runs += 1
        time.sleep(self.sleep)
        if self.runs <= self.fails:
            raise RuntimeError(f"boom {self.label} run{self.runs}")


class TestFailureIsolation:
    def test_all_failures_aggregated_with_every_label(self):
        """One bad task must not hide another: the aggregate error names
        every failed label and carries per-task records."""
        with pytest.raises(executor.ExecutionError) as ei:
            executor.execute([FakeTask("a", fails=9), FakeTask("ok"),
                              FakeTask("b", fails=9)], serial=True)
        assert "2 task(s) failed" in str(ei.value)
        assert "a" in str(ei.value) and "b" in str(ei.value)
        assert {f.label for f in ei.value.failures} == {"a", "b"}
        assert all(isinstance(f.error, RuntimeError)
                   for f in ei.value.failures)

    def test_siblings_complete_despite_failure(self):
        ok, bad = FakeTask("ok"), FakeTask("bad", fails=9)
        fails = executor.execute([bad, ok], serial=True,
                                 raise_on_error=False)
        assert ok.runs == 1
        assert [f.label for f in fails] == ["bad"]

    def test_retry_with_backoff_recovers_transient_failure(self):
        flaky = FakeTask("flaky", fails=2)
        out = executor.execute([flaky], serial=True, retries=3,
                               backoff=0.001)
        assert out == [] and flaky.runs == 3
        # exhausted retries still fail, reporting the attempt count
        dead = FakeTask("dead", fails=99)
        fails = executor.execute([dead], serial=True, retries=2,
                                 backoff=0.001, raise_on_error=False)
        assert fails[0].attempts == 3 and dead.runs == 3

    def test_non_retryable_tasks_never_retry(self):
        t = FakeTask("stream-ish", fails=1)
        t.retryable = False
        fails = executor.execute([t], serial=True, retries=5,
                                 backoff=0.001, raise_on_error=False)
        assert t.runs == 1 and fails[0].attempts == 1

    def test_dispatch_timeout_abandons_stuck_task(self):
        """Needs >= 2 workers: with one, the sibling queues behind the
        abandoned thread (timeouts only bound DISPATCHED work)."""
        slow, quick = FakeTask("slow", sleep=1.5), FakeTask("quick")
        old = executor.set_workers(max(2, executor.workers()))
        try:
            t0 = time.monotonic()
            fails = executor.execute([slow, quick], serial=False,
                                     timeout=0.3, raise_on_error=False)
            dt = time.monotonic() - t0
        finally:
            executor.set_workers(old)  # joins the abandoned sleeper
        assert dt < 1.0  # returned without waiting the sleep out
        assert [f.label for f in fails] == ["slow"]
        assert isinstance(fails[0].error, TimeoutError)
        assert quick.runs == 1


class TestStreamPrefetchShutdown:
    """The prefetch thread must stop deterministically on ANY exit from
    StreamTask.run() — normal completion, a window raising in fn, or the
    feeder itself failing — never leak waiting on a full queue."""

    @staticmethod
    def _prefetch_threads():
        return [t for t in threading.enumerate()
                if t.name.startswith("repro-stream-prefetch")]

    @staticmethod
    def _task(n_windows=64, fn=None):
        def windows(ctx):
            for i in range(n_windows):
                yield (np.full(4, i),)
        return executor.StreamTask(
            fn=fn or (lambda state, a: (state + 1, (a,))),
            pack=lambda: (0, None), windows=windows,
            consume=lambda out, ctx: None,
            finalize=lambda state, ctx: None, label="probe")

    def _assert_no_leak(self):
        deadline = time.monotonic() + 5.0
        while self._prefetch_threads() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert self._prefetch_threads() == []

    def test_normal_completion_leaves_no_thread(self):
        self._task().run()
        self._assert_no_leak()

    def test_consumer_error_stops_feeder_promptly(self):
        """fn raising on an early window: the feeder is still trying to
        queue dozens more. Shutdown must drain it out of q.put() fast."""
        def fn(state, a):
            if state == 2:
                raise RuntimeError("window exploded")
            return state + 1, (a,)

        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="window exploded"):
            self._task(n_windows=500, fn=fn).run()
        assert time.monotonic() - t0 < 5.0
        self._assert_no_leak()

    def test_feeder_error_surfaces_on_consumer(self):
        def windows(ctx):
            yield (np.zeros(1),)
            raise ValueError("generator died")

        t = self._task()
        t.windows = windows
        with pytest.raises(ValueError, match="generator died"):
            t.run()
        self._assert_no_leak()


def _identical_records(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
            np.testing.assert_array_equal(x, y)
        else:
            assert x == y, k


class TestCampaignFaultTolerance:
    def _campaign(self):
        rng = np.random.RandomState(23)
        tr1, tr2 = mk_trace(rng, 44), mk_trace(rng, 46)
        c = Campaign()
        c.add(tr1, JETSON_NANO, workload="a")
        c.add(tr2, JETSON_NANO, workload="b")       # same group as a
        c.add(tr1, JETSON_NANO, mode="nots", workload="a-nots")
        return c

    def test_checkpoint_resume_recomputes_nothing(self, tmp_path):
        ck = str(tmp_path / "ckpt")
        c = self._campaign()
        r1 = c.run(checkpoint=ck)
        assert c.last_run["loaded"] == 0 and c.last_run["computed"] == 2
        assert len(os.listdir(ck)) == 2
        c2 = self._campaign()
        r2 = c2.run(checkpoint=ck)
        assert c2.last_run["loaded"] == 2 and c2.last_run["computed"] == 0
        for a, b in zip(r1, r2):
            _identical_records(a, b)
        # and checkpointing itself never changes results
        r3 = self._campaign().run()
        for a, b in zip(r1, r3):
            _identical_records(a, b)

    def test_checkpoint_is_content_addressed(self, tmp_path):
        """A different trace in the group must MISS the old file."""
        ck = str(tmp_path / "ckpt")
        c = self._campaign()
        c.run(checkpoint=ck)
        c2 = self._campaign()
        c2.points[0].trace = mk_trace(np.random.RandomState(99), 44)
        c2.run(checkpoint=ck)
        assert c2.last_run["loaded"] == 1       # only the untouched group
        assert c2.last_run["computed"] == 1

    def test_quarantine_completes_other_groups(self, monkeypatch):
        c = self._campaign()
        baseline = self._campaign().run()
        orig = emulator.prepare_tasks

        def poisoned(trs, sysc, modes, blooms, outs):
            tasks = orig(trs, sysc, modes, blooms, outs)
            if modes[0] == "nots":
                for t in tasks:
                    def die():
                        raise RuntimeError("pack died")
                    t.pack = die
            return tasks

        monkeypatch.setattr(emulator, "prepare_tasks", poisoned)
        recs = c.run(on_error="quarantine")
        assert c.last_run["failed"] == 1 and c.last_run["computed"] == 1
        errs = [r for r in recs if "error" in r]
        assert len(errs) == 1 and errs[0]["workload"] == "a-nots"
        assert errs[0]["error_type"] == "RuntimeError"
        assert "pack died" in errs[0]["error"]
        good = [r for r in recs if "error" not in r]
        for a, b in zip([r for r in baseline
                         if r["workload"] != "a-nots"], good):
            _identical_records(a, b)
        # default on_error='raise' still raises the aggregate
        with pytest.raises(executor.ExecutionError, match="pack died"):
            self._campaign().run()

    def test_run_validates_on_error(self):
        with pytest.raises(ValueError, match="on_error"):
            Campaign().run(on_error="ignore")

    def test_killed_campaign_resumes_bit_identically(self, tmp_path):
        """The end-to-end resume contract: a process killed mid-campaign
        (first group checkpointed, second never ran) restarts, recomputes
        ZERO finished groups and produces the full result set, matching
        this process bit-for-bit."""
        child = tmp_path / "child.py"
        ck = tmp_path / "ckpt"
        cache = tmp_path / "xla_cache"
        child.write_text(
            "import json, os, sys\n"
            "from repro.utils.jax_compat import "
            "enable_persistent_compile_cache\n"
            "enable_persistent_compile_cache(sys.argv[1])\n"
            "import numpy as np\n"
            "from repro.core import emulator\n"
            "from repro.core.campaign import Campaign\n"
            "from repro.core.emulator import Trace\n"
            "from repro.core.timescale import JETSON_NANO\n"
            "rng = np.random.RandomState(29)\n"
            "def mk(n):\n"
            "    return Trace.of(kind=rng.randint(0, 2, n),\n"
            "                    bank=rng.randint(0, 16, n),\n"
            "                    row=rng.randint(0, 4096, n),\n"
            "                    delta=rng.randint(1, 8, n),\n"
            "                    dep=rng.randint(0, 2, n))\n"
            "c = Campaign()\n"
            "c.add(mk(40), JETSON_NANO, workload='w0')\n"
            "c.add(mk(40), JETSON_NANO, mode='nots', workload='w1')\n"
            "if os.environ.get('DIE_MID_CAMPAIGN'):\n"
            "    orig = emulator.prepare_tasks\n"
            "    def sabotage(trs, sysc, modes, blooms, outs):\n"
            "        ts = orig(trs, sysc, modes, blooms, outs)\n"
            "        if modes[0] == 'nots':\n"
            "            for t in ts:\n"
            "                t.pack = lambda: os._exit(9)\n"
            "        return ts\n"
            "    emulator.prepare_tasks = sabotage\n"
            "recs = c.run(serial=True, checkpoint=sys.argv[2])\n"
            "print(json.dumps({\n"
            "  'loaded': c.last_run['loaded'],\n"
            "  'computed': c.last_run['computed'],\n"
            "  'exec': [int(r['exec_cycles']) for r in recs],\n"
            "  'resp': [int(np.asarray(r['t_resp']).astype(np.int64).sum())\n"
            "           for r in recs]}))\n")
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")

        env_kill = dict(env, DIE_MID_CAMPAIGN="1")
        p1 = subprocess.run(
            [sys.executable, str(child), str(cache), str(ck)], env=env_kill,
            capture_output=True, text=True, timeout=420)
        assert p1.returncode == 9, (p1.returncode, p1.stderr[-2000:])
        files = os.listdir(ck)
        assert len(files) == 1      # group w0 persisted before the kill

        p2 = subprocess.run(
            [sys.executable, str(child), str(cache), str(ck)], env=env,
            capture_output=True, text=True, timeout=420)
        assert p2.returncode == 0, p2.stderr[-2000:]
        out = json.loads(p2.stdout.strip().splitlines()[-1])
        assert out["loaded"] == 1 and out["computed"] == 1
        assert len(os.listdir(ck)) == 2

        # bit-identity against this process, fresh compute, no checkpoint
        rng = np.random.RandomState(29)
        c = Campaign()
        c.add(mk_trace(rng, 40), JETSON_NANO, workload="w0")
        c.add(mk_trace(rng, 40), JETSON_NANO, mode="nots", workload="w1")
        here = c.run(serial=True)
        assert out["exec"] == [int(r["exec_cycles"]) for r in here]
        assert out["resp"] == [
            int(np.asarray(r["t_resp"]).astype(np.int64).sum())
            for r in here]


class TestSharding:
    def test_forced_single_device_shard_map_bit_identical(self):
        """The shard_map code path itself (1-device mesh) must be
        bit-identical to the plain vmap path — the single-device half
        of the sharding contract."""
        rng = np.random.RandomState(5)
        trs = [mk_trace(rng, 40) for _ in range(4)]
        bloom = small_bloom(5)
        old = emulator.set_sharding("force")
        try:
            a = run_many(trs, JETSON_NANO, "ts")
            ab = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        finally:
            emulator.set_sharding(old)
        b = run_many(trs, JETSON_NANO, "ts")
        bb = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        for x, y in zip(a + ab, b + bb):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])

    def test_set_sharding_validates(self):
        with pytest.raises(ValueError, match="sharding mode"):
            emulator.set_sharding("sometimes")

    def test_shard_count_divisibility(self):
        """Sharding only engages when the padded batch divides across a
        power-of-two device count; 'off' always disables."""
        old = emulator.set_sharding("off")
        try:
            assert emulator._shard_count(8) == 0
        finally:
            emulator.set_sharding(old)

    def test_multi_device_sharded_and_persistent_cache(self, tmp_path):
        """Two forced host devices in a subprocess: the shard_map'd
        batch axis must reproduce this (single-device, unsharded)
        process bit-for-bit, and a second process over the same
        persistent cache dir must skip the XLA compiles (hits > 0)."""
        child = tmp_path / "child.py"
        cache = tmp_path / "xla_cache"
        child.write_text(
            "import json, os, sys\n"
            "os.environ['XLA_FLAGS'] = (os.environ.get('XLA_FLAGS', '')\n"
            "    + ' --xla_force_host_platform_device_count=2')\n"
            "import numpy as np\n"
            "from repro.utils.jax_compat import (\n"
            "    enable_persistent_compile_cache, persistent_cache_stats)\n"
            "enable_persistent_compile_cache(sys.argv[1])\n"
            "import jax\n"
            "from repro.core import emulator\n"
            "from repro.core.emulator import Trace, run_many\n"
            "from repro.core.timescale import JETSON_NANO\n"
            "assert jax.local_device_count() == 2\n"
            "assert emulator._shard_count(4) == 2  # sharding engages\n"
            "rng = np.random.RandomState(17)\n"
            "def mk(n):\n"
            "    return Trace.of(kind=rng.randint(0, 2, n),\n"
            "                    bank=rng.randint(0, 16, n),\n"
            "                    row=rng.randint(0, 4096, n),\n"
            "                    delta=rng.randint(1, 8, n),\n"
            "                    dep=rng.randint(0, 2, n))\n"
            "trs = [mk(40), mk(42), mk(44), mk(46), mk(90), mk(95)]\n"
            "out = run_many(trs, JETSON_NANO,\n"
            "               ['ts'] * 4 + ['nots', 'nots'])\n"
            "print(json.dumps({\n"
            "  'exec': [int(r['exec_cycles']) for r in out],\n"
            "  'resp': [int(np.asarray(r['t_resp']).astype(np.int64).sum())\n"
            "           for r in out],\n"
            "  'pcache': persistent_cache_stats()}))\n")
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        outs = []
        for _ in range(2):
            p = subprocess.run(
                [sys.executable, str(child), str(cache)], env=env,
                capture_output=True, text=True, timeout=420)
            assert p.returncode == 0, p.stderr[-2000:]
            outs.append(json.loads(p.stdout.strip().splitlines()[-1]))
        first, second = outs
        # same sweep in this (single-device) process, no sharding
        rng = np.random.RandomState(17)
        trs = [mk_trace(rng, n) for n in (40, 42, 44, 46, 90, 95)]
        here = run_many(trs, JETSON_NANO, ["ts"] * 4 + ["nots", "nots"])
        assert first["exec"] == second["exec"] \
            == [int(r["exec_cycles"]) for r in here]
        assert first["resp"] == second["resp"] \
            == [int(np.asarray(r["t_resp"]).astype(np.int64).sum())
                for r in here]
        # cold process: everything misses; warm process: disk hits
        assert first["pcache"]["misses"] > 0
        assert second["pcache"]["hits"] > 0
        assert second["pcache"]["misses"] == 0


class TestCacheLRU:
    def test_lru_bounds_hundred_group_sweep(self):
        """A 100-group sweep must not retain 100 executables: the LRU
        cap bounds the cache and counts evictions; cache_clear resets
        every counter, including the new ones."""
        emulator.cache_clear()
        old = emulator.set_cache_capacity(8)
        try:
            base = emulator.compile_key(32, 1, JETSON_NANO, "ts", None, 40)
            for i in range(100):  # 100 distinct compile keys
                key = (32, 40 + 2 * i) + base[2:]
                emulator._batched_fn(key)
            st = emulator.cache_stats()
            assert st["size"] <= 8
            assert st["misses"] == 100
            assert st["evictions"] == 92
            # most-recent key is retained...
            emulator._batched_fn((32, 40 + 2 * 99) + base[2:])
            assert emulator.cache_stats()["hits"] == 1
            # ...the oldest was evicted
            emulator._batched_fn((32, 40) + base[2:])
            assert emulator.cache_stats()["misses"] == 101
            emulator.cache_clear()
            st = emulator.cache_stats()
            assert (st["hits"], st["misses"], st["evictions"], st["size"]) \
                == (0, 0, 0, 0)
        finally:
            emulator.set_cache_capacity(old)
            emulator.cache_clear()

    def test_lru_end_to_end_eviction_and_recompile(self):
        """Through the real run path: with capacity 2, a third distinct
        group evicts the first, and revisiting it recompiles (a miss,
        not a stale hit) with results unchanged."""
        rng = np.random.RandomState(31)
        t32, t64, t128 = (mk_trace(rng, n) for n in (20, 40, 80))
        emulator.cache_clear()
        old = emulator.set_cache_capacity(2)
        try:
            first = int(emulator.run(t32, JETSON_NANO, "ts")["exec_cycles"])
            emulator.run(t64, JETSON_NANO, "ts")
            emulator.run(t128, JETSON_NANO, "ts")
            st = emulator.cache_stats()
            assert st["size"] == 2 and st["evictions"] == 1
            again = emulator.run(t32, JETSON_NANO, "ts")
            st2 = emulator.cache_stats()
            assert st2["misses"] == st["misses"] + 1  # genuinely recompiled
            assert int(again["exec_cycles"]) == first
        finally:
            emulator.set_cache_capacity(old)
            emulator.cache_clear()

    def test_capacity_validation_and_shrink(self):
        with pytest.raises(ValueError, match="capacity"):
            emulator.set_cache_capacity(0)
        old = emulator.set_cache_capacity(4)
        emulator.set_cache_capacity(old)
        assert emulator.cache_stats()["capacity"] == old


class TestValueErrorGuards:
    """The mode guards must be real exceptions (asserts vanish under
    ``python -O``) and carry the offending value."""

    def test_campaign_add_bad_mode(self):
        with pytest.raises(ValueError, match="'warp'"):
            Campaign().add(mk_trace(np.random.RandomState(0), 8),
                           JETSON_NANO, mode="warp")

    def test_add_policy_grid_bad_mode(self):
        with pytest.raises(ValueError, match="'fast'"):
            Campaign().add_policy_grid(
                mk_trace(np.random.RandomState(0), 8), JETSON_NANO,
                [smcprog.frfcfs_program()], mode="fast")

    def test_run_many_bad_mode(self):
        tr = mk_trace(np.random.RandomState(0), 8)
        with pytest.raises(ValueError, match="'emu'"):
            run_many([tr], JETSON_NANO, "emu")
        with pytest.raises(ValueError, match="match len"):
            run_many([tr, tr], JETSON_NANO, ["ts"])

    def test_run_bad_mode(self):
        with pytest.raises(ValueError, match="'x'"):
            emulator.run(mk_trace(np.random.RandomState(0), 8),
                         JETSON_NANO, "x")
