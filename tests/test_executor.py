"""The overlapped campaign executor (PR 5): bit-identity of overlapped /
sharded execution vs the serial PR 4 group loop, add-order preservation,
the LRU bound on the in-memory executable cache, the persistent on-disk
compile cache across processes, and the ValueError API guards."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import emulator, executor, smcprog
from repro.core.bloom import BloomFilter
from repro.core.campaign import Campaign
from repro.core.emulator import Trace, run_many
from repro.core.timescale import JETSON_NANO


def mk_trace(rng, n):
    return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                    row=rng.randint(0, 4096, n), delta=rng.randint(1, 8, n),
                    dep=rng.randint(0, 2, n))


def small_bloom(seed=0):
    rng = np.random.RandomState(seed)
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 150).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    return (bf.bits, bf.k, bf.m_bits)


def mixed_grid_campaign(seed=3):
    """A heterogeneous grid spanning modes x policies x bloom arms x two
    length buckets — the shape the overlapped executor must keep
    bit-identical to the serial loop."""
    rng = np.random.RandomState(seed)
    trs = [mk_trace(rng, n) for n in (40, 44, 90, 95)]
    bloom = small_bloom(seed)
    prog = smcprog.frfcfs_program()
    c = Campaign()
    for i, tr in enumerate(trs):
        for mode in ("ts", "nots"):
            c.add(tr, JETSON_NANO, mode=mode, i=i, arm="plain")
        c.add(tr, JETSON_NANO, mode="ts", bloom=bloom, i=i, arm="bloom")
        c.add_policy_grid(tr, JETSON_NANO, [prog], mode="ts",
                          derive_cost=False, i=i, arm="policy")
    return c


class TestOverlapBitIdentity:
    def test_campaign_overlapped_matches_serial(self):
        c = mixed_grid_campaign()
        assert c.n_groups() >= 6  # genuinely heterogeneous
        a = c.run(serial=True)
        b = c.run()
        assert len(a) == len(b) == len(c)
        for x, y in zip(a, b):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            assert int(x["row_hits"]) == int(y["row_hits"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])
            np.testing.assert_array_equal(x["t_issue"], y["t_issue"])
            assert x["mode"] == y["mode"]

    def test_run_many_overlapped_matches_serial(self):
        rng = np.random.RandomState(11)
        trs = [mk_trace(rng, n) for n in (35, 70, 140, 40, 80)]
        modes = ["ts", "nots", "ts", "reference", "ts"]
        a = run_many(trs, JETSON_NANO, modes, serial=True)
        b = run_many(trs, JETSON_NANO, modes)
        for x, y in zip(a, b):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])

    def test_add_order_preserved(self):
        """Records come back in add order even though groups execute
        concurrently and finish in arbitrary order."""
        c = mixed_grid_campaign(seed=9)
        for j, p in enumerate(c.points):
            p.meta["seq"] = j
        recs = c.run()
        assert [r["seq"] for r in recs] == list(range(len(c)))
        # and per-point identity against the single-trace path
        k = len(c) // 2
        p = c.points[k]
        solo = emulator.run(p.trace, p.sys, p.mode, bloom=p.bloom)
        assert int(solo["exec_cycles"]) == int(recs[k]["exec_cycles"])

    def test_executor_propagates_worker_errors(self):
        def boom():
            raise RuntimeError("pack failed")
        tasks = [executor.GroupTask(fn=lambda: None, pack=boom,
                                    finalize=lambda o, c: None)
                 for _ in range(3)]
        with pytest.raises(RuntimeError, match="pack failed"):
            executor.execute(tasks, serial=False)

    def test_set_workers_validates_and_restores(self):
        old = executor.set_workers(1)
        try:
            # workers=1 forces the serial fallback; results unchanged
            rng = np.random.RandomState(2)
            trs = [mk_trace(rng, 40), mk_trace(rng, 90)]
            out = run_many(trs, JETSON_NANO, ["ts", "nots"])
            assert all(r is not None for r in out)
            with pytest.raises(ValueError, match="worker count"):
                executor.set_workers(0)
        finally:
            executor.set_workers(old)


class TestSharding:
    def test_forced_single_device_shard_map_bit_identical(self):
        """The shard_map code path itself (1-device mesh) must be
        bit-identical to the plain vmap path — the single-device half
        of the sharding contract."""
        rng = np.random.RandomState(5)
        trs = [mk_trace(rng, 40) for _ in range(4)]
        bloom = small_bloom(5)
        old = emulator.set_sharding("force")
        try:
            a = run_many(trs, JETSON_NANO, "ts")
            ab = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        finally:
            emulator.set_sharding(old)
        b = run_many(trs, JETSON_NANO, "ts")
        bb = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        for x, y in zip(a + ab, b + bb):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
            np.testing.assert_array_equal(x["t_resp"], y["t_resp"])

    def test_set_sharding_validates(self):
        with pytest.raises(ValueError, match="sharding mode"):
            emulator.set_sharding("sometimes")

    def test_shard_count_divisibility(self):
        """Sharding only engages when the padded batch divides across a
        power-of-two device count; 'off' always disables."""
        old = emulator.set_sharding("off")
        try:
            assert emulator._shard_count(8) == 0
        finally:
            emulator.set_sharding(old)

    def test_multi_device_sharded_and_persistent_cache(self, tmp_path):
        """Two forced host devices in a subprocess: the shard_map'd
        batch axis must reproduce this (single-device, unsharded)
        process bit-for-bit, and a second process over the same
        persistent cache dir must skip the XLA compiles (hits > 0)."""
        child = tmp_path / "child.py"
        cache = tmp_path / "xla_cache"
        child.write_text(
            "import json, os, sys\n"
            "os.environ['XLA_FLAGS'] = (os.environ.get('XLA_FLAGS', '')\n"
            "    + ' --xla_force_host_platform_device_count=2')\n"
            "import numpy as np\n"
            "from repro.utils.jax_compat import (\n"
            "    enable_persistent_compile_cache, persistent_cache_stats)\n"
            "enable_persistent_compile_cache(sys.argv[1])\n"
            "import jax\n"
            "from repro.core import emulator\n"
            "from repro.core.emulator import Trace, run_many\n"
            "from repro.core.timescale import JETSON_NANO\n"
            "assert jax.local_device_count() == 2\n"
            "assert emulator._shard_count(4) == 2  # sharding engages\n"
            "rng = np.random.RandomState(17)\n"
            "def mk(n):\n"
            "    return Trace.of(kind=rng.randint(0, 2, n),\n"
            "                    bank=rng.randint(0, 16, n),\n"
            "                    row=rng.randint(0, 4096, n),\n"
            "                    delta=rng.randint(1, 8, n),\n"
            "                    dep=rng.randint(0, 2, n))\n"
            "trs = [mk(40), mk(42), mk(44), mk(46), mk(90), mk(95)]\n"
            "out = run_many(trs, JETSON_NANO,\n"
            "               ['ts'] * 4 + ['nots', 'nots'])\n"
            "print(json.dumps({\n"
            "  'exec': [int(r['exec_cycles']) for r in out],\n"
            "  'resp': [int(np.asarray(r['t_resp']).astype(np.int64).sum())\n"
            "           for r in out],\n"
            "  'pcache': persistent_cache_stats()}))\n")
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        outs = []
        for _ in range(2):
            p = subprocess.run(
                [sys.executable, str(child), str(cache)], env=env,
                capture_output=True, text=True, timeout=420)
            assert p.returncode == 0, p.stderr[-2000:]
            outs.append(json.loads(p.stdout.strip().splitlines()[-1]))
        first, second = outs
        # same sweep in this (single-device) process, no sharding
        rng = np.random.RandomState(17)
        trs = [mk_trace(rng, n) for n in (40, 42, 44, 46, 90, 95)]
        here = run_many(trs, JETSON_NANO, ["ts"] * 4 + ["nots", "nots"])
        assert first["exec"] == second["exec"] \
            == [int(r["exec_cycles"]) for r in here]
        assert first["resp"] == second["resp"] \
            == [int(np.asarray(r["t_resp"]).astype(np.int64).sum())
                for r in here]
        # cold process: everything misses; warm process: disk hits
        assert first["pcache"]["misses"] > 0
        assert second["pcache"]["hits"] > 0
        assert second["pcache"]["misses"] == 0


class TestCacheLRU:
    def test_lru_bounds_hundred_group_sweep(self):
        """A 100-group sweep must not retain 100 executables: the LRU
        cap bounds the cache and counts evictions; cache_clear resets
        every counter, including the new ones."""
        emulator.cache_clear()
        old = emulator.set_cache_capacity(8)
        try:
            base = emulator.compile_key(32, 1, JETSON_NANO, "ts", None, 40)
            for i in range(100):  # 100 distinct compile keys
                key = (32, 40 + 2 * i) + base[2:]
                emulator._batched_fn(key)
            st = emulator.cache_stats()
            assert st["size"] <= 8
            assert st["misses"] == 100
            assert st["evictions"] == 92
            # most-recent key is retained...
            emulator._batched_fn((32, 40 + 2 * 99) + base[2:])
            assert emulator.cache_stats()["hits"] == 1
            # ...the oldest was evicted
            emulator._batched_fn((32, 40) + base[2:])
            assert emulator.cache_stats()["misses"] == 101
            emulator.cache_clear()
            st = emulator.cache_stats()
            assert (st["hits"], st["misses"], st["evictions"], st["size"]) \
                == (0, 0, 0, 0)
        finally:
            emulator.set_cache_capacity(old)
            emulator.cache_clear()

    def test_lru_end_to_end_eviction_and_recompile(self):
        """Through the real run path: with capacity 2, a third distinct
        group evicts the first, and revisiting it recompiles (a miss,
        not a stale hit) with results unchanged."""
        rng = np.random.RandomState(31)
        t32, t64, t128 = (mk_trace(rng, n) for n in (20, 40, 80))
        emulator.cache_clear()
        old = emulator.set_cache_capacity(2)
        try:
            first = int(emulator.run(t32, JETSON_NANO, "ts")["exec_cycles"])
            emulator.run(t64, JETSON_NANO, "ts")
            emulator.run(t128, JETSON_NANO, "ts")
            st = emulator.cache_stats()
            assert st["size"] == 2 and st["evictions"] == 1
            again = emulator.run(t32, JETSON_NANO, "ts")
            st2 = emulator.cache_stats()
            assert st2["misses"] == st["misses"] + 1  # genuinely recompiled
            assert int(again["exec_cycles"]) == first
        finally:
            emulator.set_cache_capacity(old)
            emulator.cache_clear()

    def test_capacity_validation_and_shrink(self):
        with pytest.raises(ValueError, match="capacity"):
            emulator.set_cache_capacity(0)
        old = emulator.set_cache_capacity(4)
        emulator.set_cache_capacity(old)
        assert emulator.cache_stats()["capacity"] == old


class TestValueErrorGuards:
    """The mode guards must be real exceptions (asserts vanish under
    ``python -O``) and carry the offending value."""

    def test_campaign_add_bad_mode(self):
        with pytest.raises(ValueError, match="'warp'"):
            Campaign().add(mk_trace(np.random.RandomState(0), 8),
                           JETSON_NANO, mode="warp")

    def test_add_policy_grid_bad_mode(self):
        with pytest.raises(ValueError, match="'fast'"):
            Campaign().add_policy_grid(
                mk_trace(np.random.RandomState(0), 8), JETSON_NANO,
                [smcprog.frfcfs_program()], mode="fast")

    def test_run_many_bad_mode(self):
        tr = mk_trace(np.random.RandomState(0), 8)
        with pytest.raises(ValueError, match="'emu'"):
            run_many([tr], JETSON_NANO, "emu")
        with pytest.raises(ValueError, match="match len"):
            run_many([tr, tr], JETSON_NANO, ["ts"])

    def test_run_bad_mode(self):
        with pytest.raises(ValueError, match="'x'"):
            emulator.run(mk_trace(np.random.RandomState(0), 8),
                         JETSON_NANO, "x")
