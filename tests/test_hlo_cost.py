"""Loop-aware HLO cost analyzer vs hand-counted programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import hlo_cost
from repro.utils.jax_compat import cost_analysis_dict


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    r = hlo_cost.analyze(c.as_text())
    assert abs(r["flops"] / (2 * 512 ** 3) - 1.0) < 0.01


def test_scan_trip_count_multiplied():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def f(x, ws):
        return jax.lax.scan(lambda x, w: (x @ w, None), x, ws)[0]

    c = jax.jit(f).lower(a, w).compile()
    r = hlo_cost.analyze(c.as_text())
    assert abs(r["flops"] / (10 * 2 * 256 ** 3) - 1.0) < 0.01
    # raw XLA undercounts by the trip count — the bug this module fixes
    assert cost_analysis_dict(c)["flops"] < r["flops"] / 5


def test_nested_scan():
    b = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 4, 128, 128), jnp.float32)

    def g(x, ws):
        def outer(x, wo):
            return jax.lax.scan(lambda x, wi: (jnp.tanh(x @ wi), None), x, wo)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(g).lower(b, w).compile()
    r = hlo_cost.analyze(c.as_text())
    assert abs(r["flops"] / (12 * 2 * 128 ** 3) - 1.0) < 0.01


def test_collectives_counted_per_kind():
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys; sys.path.insert(0, "src")
from repro.utils import hlo_cost
mesh = jax.make_mesh((8,), ("d",))
a = jax.ShapeDtypeStruct((1024, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P("d", None)))
def f(x):
    y = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(None, None)))
    return y.sum()
with mesh:
    c = jax.jit(f).lower(a).compile()
r = hlo_cost.analyze(c.as_text())
assert r["all-gather"] > 0, r
print("COLL_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=240, cwd=repo)
    assert "COLL_OK" in res.stdout, res.stdout + res.stderr[-1500:]
