"""Per-arch reduced-config smoke tests: one train step on CPU, output
shapes + finite loss (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, MoEConfig, SSMConfig, get_config
from repro.models import model_zoo
from tests.conftest import tiny_cfg

REDUCED = {
    "glm4_9b": {},
    "qwen2_1_5b": {},
    "qwen3_8b": {},
    "gemma_7b": {},
    "llava_next_34b": {"n_patches": 8},
    "whisper_base": {"n_enc_layers": 2, "n_frames": 16, "n_kv_heads": 4},
    "jamba_v0_1_52b": {"n_layers": 8,
                       "moe": MoEConfig(n_experts=4, top_k=2, d_ff=128, every=2),
                       "ssm": SSMConfig(d_state=8, d_conv=4, expand=2, chunk=16)},
    "granite_moe_1b_a400m": {"moe": MoEConfig(n_experts=4, top_k=2, d_ff=64)},
    "qwen3_moe_30b_a3b": {"moe": MoEConfig(n_experts=8, top_k=2, d_ff=64)},
    "rwkv6_3b": {"n_heads": 4, "n_kv_heads": 4, "ssm": SSMConfig(chunk=16)},
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = tiny_cfg(arch, **REDUCED[arch])
    B, S = 2, 32
    model = model_zoo.build(cfg, s_max=S)
    params = model.init(rng)
    batch = {"tokens": jnp.ones((B, S if cfg.family != "vlm" else S - cfg.n_patches),
                                jnp.int32),
             "targets": jnp.ones((B, S if cfg.family != "vlm" else S - cfg.n_patches),
                                 jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.n_frames, cfg.d_model), jnp.float32)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), arch
    assert loss.shape == ()
    # grads flow and are finite
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(g))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_registry(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    assert cfg.padded_vocab % 2048 == 0 and cfg.padded_vocab >= cfg.vocab_size
