"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU = kernel body executed exactly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import BloomFilter
from repro.kernels import ops, ref


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 8, 2, 64),    # GQA 4:1
    (1, 256, 8, 8, 128),   # MHA hd=128
    (1, 128, 4, 1, 256),   # MQA hd=256 (gemma-style)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    o = ops.flash_attention(q, k, v, causal=causal)
    G = H // KV
    qr = q.transpose(0, 2, 1, 3).reshape(B, KV, G, S, hd).reshape(-1, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(-1, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(-1, S, hd)
    oref = (ref.flash_attention_ref(qr, kr, vr, causal)
            .reshape(B, KV, G, S, hd).reshape(B, H, S, hd).transpose(0, 2, 1, 3))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("m_bits,k,n", [(1 << 14, 2, 100), (1 << 16, 4, 5000),
                                        (1 << 18, 6, 20000)])
def test_bloom_probe_sweep(m_bits, k, n):
    keys_in = np.arange(0, n * 3, 3, dtype=np.uint32)
    bf = BloomFilter.build(keys_in, m_bits=m_bits, k=k)
    probes = np.arange(0, n * 4, dtype=np.uint32)
    got = np.asarray(ops.bloom_probe(bf.bits, probes, k=k, m_bits=m_bits))
    want = np.asarray(ref.bloom_probe_ref(bf.bits, jnp.asarray(probes), k, m_bits))
    np.testing.assert_array_equal(got, want)
    # zero false negatives on inserted keys
    assert np.asarray(ops.bloom_probe(bf.bits, keys_in, k=k, m_bits=m_bits)).all()


@pytest.mark.parametrize("shape", [(8, 128), (64, 512), (33, 257), (1, 8192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_rowclone_copy_sweep(shape, dtype):
    x = jnp.arange(np.prod(shape)).reshape(shape).astype(dtype)
    y = ops.rowclone_copy(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.rowclone_copy_ref(x)))
