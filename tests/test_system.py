"""End-to-end behaviour: train -> checkpoint -> serve on one model, the
serve engine's fork path, subprocess dry-run, and pipeline parallelism."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SSMConfig
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.models import model_zoo
from repro.serve.engine import ServeEngine
from repro.train import optimizer as opt
from repro.train.trainer import Trainer
from tests.conftest import tiny_cfg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_then_serve_end_to_end(tmp_path):
    cfg = tiny_cfg("qwen3_8b", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, head_dim=16)
    model = model_zoo.build(cfg, s_max=24)
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=9, n_patterns=4)
    tr = Trainer(model, opt.AdamWConfig(lr=5e-3, warmup=5, total_steps=200))
    state = tr.init_state()
    state, hist = tr.run(state, iter(ShardedLoader(src)), steps=40, log_every=0)
    assert hist[-1] < hist[0]

    params = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), state.master)
    eng = ServeEngine(model, params, s_max=24)
    prompt = np.asarray(src.batch(0)["tokens"])[0, :8]
    out = eng.generate(prompt, max_new=8)
    assert len(out) == 8 and all(0 <= t < cfg.vocab_size for t in out)

    outs = eng.generate_batch(np.asarray(src.batch(1)["tokens"])[:4, :8], 6)
    assert outs.shape == (4, 6)


def test_serve_fork_kernel_matches_tile():
    cfg = tiny_cfg("qwen3_8b", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, head_dim=16)
    model = model_zoo.build(cfg, s_max=16)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, s_max=16)
    _, cache = model.prefill_fn(params, {"tokens": jnp.ones((1, 16), jnp.int32)})
    f1 = eng.fork_cache(cache, 3, use_kernel=False)
    f2 = eng.fork_cache(cache, 3, use_kernel=True)
    for a, b in zip(jax.tree_util.tree_leaves(f1), jax.tree_util.tree_leaves(f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dryrun_subprocess_cell():
    """Deliverable (e): lower+compile a full-size cell on the production
    mesh inside a clean interpreter (512 host devices)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper_base",
         "--shape", "decode_32k", "--force"],
        capture_output=True, text=True, timeout=520, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "[ok]" in r.stdout


def test_pipeline_parallel_subprocess():
    """PP over 4 host devices == sequential stack (exactness)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, sequential_apply
mesh = jax.make_mesh((4,), ("pod",))
S, M, mb, d = 4, 8, 2, 16
k = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(k, (S, d, d)) * 0.3}
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
stage = lambda p, x: jnp.tanh(x @ p["w"])
a = pipeline_apply(params, x, stage, mesh, axis="pod")
b = sequential_apply(params, x, stage)
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
print("PIPELINE_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr[-2000:]


def test_elastic_remesh_subprocess():
    """Node-loss drill: reshard ZeRO-1 state from 8 -> 4 devices."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import model_zoo
from repro.sharding.rules import Rules
from repro.distributed.elastic import remesh_state, healthy_mesh
from repro.train import optimizer as opt
from jax.sharding import NamedSharding

cfg = get_config("qwen2_1_5b").scaled(n_layers=2, d_model=64, n_heads=4,
                                      n_kv_heads=2, d_ff=128, vocab_size=512,
                                      head_dim=16)
model = model_zoo.build(cfg, s_max=16)
mesh8 = healthy_mesh(8, model_parallel=2)
rules8 = Rules(mesh8)
specs = opt.state_pspecs(model.defs, rules8)
state = opt.init_state(model.init(jax.random.PRNGKey(0)))
state = jax.tree_util.tree_map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh8, s)), state, specs)
before = np.asarray(jax.tree_util.tree_leaves(state.master)[0])
mesh4 = healthy_mesh(4, model_parallel=2)   # two nodes died
state4, _ = remesh_state(state, model, mesh4)
after = np.asarray(jax.tree_util.tree_leaves(state4.master)[0])
np.testing.assert_array_equal(before, after)
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr[-2000:]
