"""Streaming chunked-window driver: bit-identity with single-shot,
length-independent compile keys, trace-file ingestion, error paths.

The anchor contract (ISSUE 7): ``run_stream(chunks) == run(whole)``
bit-for-bit on any size both paths support — across chunk sizes, modes,
windows, deps, mid-trace NOP runs, Bloom arms and policy programs —
while a stream's compile key never depends on total trace length.
hypothesis widens the same properties when installed
(tests/test_property.py); the randomized sweeps here run everywhere.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import emulator, smcprog, traces
from repro.core.bloom import BloomFilter
from repro.core.cachesim import LLC
from repro.core.emulator import (
    BIG, EmulatorState, Trace, run, run_many, run_ref, run_stream,
    run_stream_many)
from repro.core.timescale import JETSON_NANO

GEO = JETSON_NANO.geometry

AGG_KEYS = ("exec_cycles", "row_hits", "served", "dram_ticks",
            "smc_fpga_cycles")


def random_trace(rng, n, kinds=5, dep_max=3, nop_run=0):
    kind = rng.randint(0, kinds, n)
    if nop_run and n > nop_run:
        at = int(rng.randint(0, n - nop_run))
        kind[at:at + nop_run] = 4
    return Trace.of(kind=kind, bank=rng.randint(0, 16, n),
                    row=rng.randint(0, 4096, n),
                    delta=rng.randint(0, 24, n),
                    dep=rng.randint(0, dep_max + 1, n))


def assert_stream_equal(single, streamed, n):
    for k in AGG_KEYS:
        assert int(single[k]) == int(streamed[k]), k
    assert single["avg_load_latency_cycles"] == \
        streamed["avg_load_latency_cycles"]
    assert single["exec_seconds"] == streamed["exec_seconds"]
    if "t_resp" in streamed:
        np.testing.assert_array_equal(single["t_resp"][:n],
                                      streamed["t_resp"])
        np.testing.assert_array_equal(single["t_issue"][:n],
                                      streamed["t_issue"])


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk,window,mode", [
    (5, 16, 4, "ts"),          # stream shorter than one chunk
    (31, 12, 1, "ts"),         # W=1, chunk straddles nothing evenly
    (33, 16, 2, "nots"),       # bucket-boundary length
    (100, 16, 4, "reference"),
    (257, 32, 8, "ts"),        # deep window
    (640, 100, 4, "nots"),     # non-power-of-two chunk
])
def test_stream_bit_identical_to_run(n, chunk, window, mode):
    rng = np.random.RandomState(n * 7 + chunk)
    tr = random_trace(rng, n)
    sysc = dataclasses.replace(JETSON_NANO, window=window)
    a = run(tr, sysc, mode)
    s = run_stream(tr, sysc, mode, chunk=chunk)
    assert int(a["served"]) == tr.n_real
    assert_stream_equal(a, s, n)


def test_stream_randomized_chunk_boundaries():
    """Many random (length, chunk) pairs, incl. mid-trace NOP runs that
    cross chunk boundaries — the no-hypothesis version of the property
    in tests/test_property.py."""
    rng = np.random.RandomState(0)
    for _ in range(10):
        n = int(rng.randint(1, 400))
        chunk = int(rng.randint(8, 64))
        nop_run = int(rng.randint(0, 80)) if n > 100 else 0
        tr = random_trace(rng, n, nop_run=nop_run)
        w = int(rng.choice([1, 2, 4]))
        sysc = dataclasses.replace(
            JETSON_NANO, window=w,
            scheduler=str(rng.choice(["frfcfs", "fcfs"])))
        a = run(tr, sysc, "ts")
        s = run_stream(tr, sysc, "ts", chunk=chunk)
        assert_stream_equal(a, s, n)


def test_stream_matches_reference_engine():
    """run_ref A/B at small sizes — the acceptance criterion's anchor:
    the streamed result equals the kept pre-optimization engine too."""
    rng = np.random.RandomState(3)
    tr = random_trace(rng, 48)
    for mode in ("ts", "nots", "reference"):
        r = run_ref(tr, JETSON_NANO, mode)
        s = run_stream(tr, JETSON_NANO, mode, chunk=16)
        assert_stream_equal(r, s, tr.n)


def test_stream_mid_trace_nop_run_crossing_chunks():
    """A 60-NOP run spanning several 16-request chunks: the frozen-slot
    handoff must reproduce the idle-hop-on-empty-queue semantics."""
    rng = np.random.RandomState(11)
    tr = random_trace(rng, 120)
    tr.kind[20:80] = 4
    tr.delta[20:80] = 5  # NOPs carry compute time
    a = run(tr, JETSON_NANO, "ts")
    s = run_stream(tr, JETSON_NANO, "ts", chunk=16)
    assert_stream_equal(a, s, tr.n)


def test_stream_many_matches_run_many_mixed_modes():
    rng = np.random.RandomState(5)
    trs = [random_trace(rng, n) for n in (40, 300, 7)]
    modes = ["ts", "nots", "reference"]
    aa = run_many(trs, JETSON_NANO, modes)
    ss = run_stream_many(trs, JETSON_NANO, modes, chunk=32)
    for tr, a, s in zip(trs, aa, ss):
        assert_stream_equal(a, s, tr.n)


def test_stream_windowed_iterator_and_factory_inputs():
    """Feeding pre-sliced windows (odd sizes) or a generator factory is
    identical to feeding the whole Trace."""
    rng = np.random.RandomState(9)
    tr = random_trace(rng, 150)
    a = run_stream(tr, JETSON_NANO, "ts", chunk=32)
    b = run_stream(traces.iter_windows(tr, 7), JETSON_NANO, "ts", chunk=32)
    c = run_stream(lambda: traces.iter_windows(tr, 41), JETSON_NANO, "ts",
                   chunk=32)
    assert_stream_equal(a, b, tr.n)
    assert_stream_equal(a, c, tr.n)
    np.testing.assert_array_equal(
        a["t_resp"], run(tr, JETSON_NANO, "ts")["t_resp"][:tr.n])


def test_stream_bloom_shared_and_stacked():
    rng = np.random.RandomState(13)
    mk = lambda n_keys: BloomFilter.build(  # noqa: E731
        rng.randint(0, 1 << 19, n_keys).astype(np.uint32),
        m_bits=1 << 14, k=3)
    bf, bf2 = mk(100), mk(50)
    bl = (bf.bits, bf.k, bf.m_bits)
    bl2 = (bf2.bits, bf2.k, bf2.m_bits)
    trs = [random_trace(rng, 90), random_trace(rng, 40)]
    a = run(trs[0], JETSON_NANO, "ts", bloom=bl)
    s = run_stream(trs[0], JETSON_NANO, "ts", bloom=bl, chunk=16)
    assert_stream_equal(a, s, trs[0].n)
    aa = run_many(trs, JETSON_NANO, "ts", blooms=[bl, bl2])
    ss = run_stream_many(trs, JETSON_NANO, "ts", blooms=[bl, bl2], chunk=16)
    for tr, x, y in zip(trs, aa, ss):
        assert_stream_equal(x, y, tr.n)


def test_stream_policy_program():
    rng = np.random.RandomState(17)
    tr = random_trace(rng, 80)
    sysp = dataclasses.replace(JETSON_NANO, policy=smcprog.frfcfs_program())
    a = run(tr, sysp, "ts")
    s = run_stream(tr, sysp, "ts", chunk=16)
    assert_stream_equal(a, s, tr.n)


def test_stream_aggregate_mode_matches_full():
    rng = np.random.RandomState(19)
    tr = random_trace(rng, 200)
    f = run_stream(tr, JETSON_NANO, "ts", chunk=32)
    g = run_stream(tr, JETSON_NANO, "ts", chunk=32, collect="aggregate")
    assert "t_resp" not in g and "t_issue" not in g
    for k in AGG_KEYS:
        assert int(f[k]) == int(g[k]), k
    assert f["avg_load_latency_cycles"] == g["avg_load_latency_cycles"]
    assert f["n_requests"] == g["n_requests"] == tr.n_real


def test_stream_empty_and_all_nop_streams():
    z = run_stream(iter([]), JETSON_NANO, "ts", chunk=16)
    assert int(z["served"]) == 0 and z["n_requests"] == 0
    assert z["avg_load_latency_cycles"] == 0.0
    nop = Trace.of(kind=np.full(50, 4), bank=np.zeros(50),
                   row=np.zeros(50), delta=np.ones(50))
    s = run_stream(nop, JETSON_NANO, "ts", chunk=16)
    assert int(s["served"]) == 0 and s["n_requests"] == 0


# ---------------------------------------------------------------------------
# compile-cache behavior: ONE streaming key, whatever the length
# ---------------------------------------------------------------------------

def test_stream_single_compile_key_across_lengths():
    """The LRU regression of ISSUE 7: a long stream holds exactly one
    streaming compile key, and a DIFFERENT total length adds none —
    where the padded single-shot path would fork a key per bucket."""
    rng = np.random.RandomState(23)
    emulator.cache_clear()
    run_stream(random_trace(rng, 640), JETSON_NANO, "ts", chunk=32)
    st = emulator.cache_stats()
    assert st["misses"] == 1 and st["size"] == 1
    run_stream(random_trace(rng, 1024), JETSON_NANO, "ts", chunk=32)
    run_stream(random_trace(rng, 100), JETSON_NANO, "ts", chunk=32)
    st = emulator.cache_stats()
    assert st["misses"] == 1, "stream compile key depends on trace length"
    assert st["size"] == 1
    assert st["hits"] == 2
    # a different chunk is a genuinely different program -> new key
    run_stream(random_trace(rng, 100), JETSON_NANO, "ts", chunk=64)
    assert emulator.cache_stats()["misses"] == 2


def test_stream_compile_key_is_length_free():
    key = emulator.stream_compile_key(64, 3, JETSON_NANO, "ts")
    assert key[0] == "stream"
    assert key == emulator.stream_compile_key(64, 3, JETSON_NANO,
                                              "reference")
    assert key != emulator.stream_compile_key(128, 3, JETSON_NANO, "ts")
    assert key != emulator.stream_compile_key(64, 3, JETSON_NANO, "nots")


# ---------------------------------------------------------------------------
# EmulatorState explicit carry
# ---------------------------------------------------------------------------

def test_emulator_state_roundtrip():
    st = EmulatorState.init(32, JETSON_NANO)
    d = st.to_host()
    assert isinstance(d, dict) and isinstance(d["bank"], dict)
    assert d["t_resp"].shape == (32,) and int(d["ptr"]) == 0
    back = EmulatorState.from_host(d)
    a = jtu_leaves(st)
    b = jtu_leaves(back)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def jtu_leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


# ---------------------------------------------------------------------------
# error paths (python -O safe)
# ---------------------------------------------------------------------------

def test_pad_trace_raises_with_lengths():
    tr = Trace.of(kind=np.zeros(10), bank=np.zeros(10), row=np.zeros(10),
                  delta=np.zeros(10))
    with pytest.raises(ValueError, match="10.*5|5.*10"):
        emulator.pad_trace(tr, 5)


def test_normalize_blooms_raises():
    bf = BloomFilter.build(np.arange(10, dtype=np.uint32),
                           m_bits=1 << 10, k=2)
    bl = (bf.bits, bf.k, bf.m_bits)
    with pytest.raises(ValueError, match="must match len"):
        emulator._normalize_blooms([bl, bl, bl], 2)
    bf2 = BloomFilter.build(np.arange(10, dtype=np.uint32),
                            m_bits=1 << 11, k=2)
    with pytest.raises(ValueError, match="must share"):
        emulator._normalize_blooms([bl, (bf2.bits, bf2.k, bf2.m_bits)], 2)


def test_stream_chunk_and_dep_validation():
    tr = Trace.of(kind=np.zeros(10), bank=np.zeros(10), row=np.zeros(10),
                  delta=np.zeros(10))
    with pytest.raises(ValueError, match="halo"):
        run_stream(tr, JETSON_NANO, "ts", chunk=4)
    with pytest.raises(ValueError, match="collect"):
        run_stream(tr, JETSON_NANO, "ts", chunk=16, collect="bogus")
    deep = Trace.of(kind=np.zeros(10), bank=np.zeros(10), row=np.zeros(10),
                    delta=np.zeros(10), dep=np.full(10, 20))
    with pytest.raises(ValueError, match="dep_max"):
        run_stream(deep, JETSON_NANO, "ts", chunk=16)
    # ... but a larger dep_max admits it (halo grows to match)
    a = run(deep, JETSON_NANO, "ts")
    s = run_stream(deep, JETSON_NANO, "ts", chunk=32, dep_max=20)
    assert_stream_equal(a, s, deep.n)
    with pytest.raises(TypeError, match="Trace"):
        run_stream(iter([np.zeros(4)]), JETSON_NANO, "ts", chunk=16)
    with pytest.raises(ValueError, match="mode"):
        run_stream(tr, JETSON_NANO, "bogus", chunk=16)


# ---------------------------------------------------------------------------
# trace files (workload zoo front door)
# ---------------------------------------------------------------------------

def test_load_trace_file_formats(tmp_path):
    p = tmp_path / "a.trace"
    p.write_text("# ramulator style\n"
                 "0x1A40 R\n"
                 "256 W\n"
                 "// comment\n"
                 "W 0x2000\n"
                 "4096\n")
    tr = traces.load_trace_file(str(p), GEO)
    assert tr.n == 4
    assert list(tr.kind) == [0, 1, 1, 0]  # READ, WRITE, WRITE, READ
    bank, row = traces.addr_to_bank_row(
        np.array([0x1A40, 256, 0x2000, 4096]), GEO)
    np.testing.assert_array_equal(tr.bank, bank)
    np.testing.assert_array_equal(tr.row, row)

    q = tmp_path / "b.csv"
    q.write_text("1000,ReadReq,0x2000\n"
                 "2000, WriteReq, 8192, 64\n"
                 "3000,rd,0x100\n")
    tc = traces.load_trace_file(str(q), GEO)
    assert tc.n == 3 and list(tc.kind) == [0, 1, 0]

    # delta / window_dep plumb through to the Trace
    td = traces.load_trace_file(str(q), GEO, delta=3, window_dep=1)
    assert set(td.delta.tolist()) == {3} and set(td.dep.tolist()) == {1}


def test_load_trace_file_bad_line_names_location(tmp_path):
    p = tmp_path / "bad.trace"
    p.write_text("0x10 R\nwhat even is this\n")
    with pytest.raises(ValueError, match=r"bad\.trace:2"):
        traces.load_trace_file(str(p), GEO)
    p2 = tmp_path / "bad2.trace"
    p2.write_text("zzz W\n")
    with pytest.raises(ValueError, match=r"bad2\.trace:1.*zzz"):
        traces.load_trace_file(str(p2), GEO)


def test_trace_file_windows_equal_whole_and_stream(tmp_path):
    p = tmp_path / "c.trace"
    p.write_text("".join(f"{i * 64} {'W' if i % 3 else 'R'}\n"
                         for i in range(1000)))
    whole = traces.load_trace_file(str(p), GEO, llc=LLC())
    parts = list(traces.iter_trace_file_windows(str(p), GEO, window=128,
                                                llc=LLC()))
    for f in ("kind", "bank", "row", "delta", "dep"):
        np.testing.assert_array_equal(
            getattr(whole, f),
            np.concatenate([getattr(w, f) for w in parts]))
    a = run(whole, JETSON_NANO, "ts")
    s = run_stream(
        lambda: traces.iter_trace_file_windows(str(p), GEO, window=128,
                                               llc=LLC()),
        JETSON_NANO, "ts", chunk=64)
    assert_stream_equal(a, s, whole.n)
    # max_requests bounds the CPU-level stream
    few = traces.load_trace_file(str(p), GEO, max_requests=10)
    assert few.n == 10


def test_synthetic_stream_reproducible():
    a = list(traces.synthetic_stream(5000, window=777, seed=3))
    b = list(traces.synthetic_stream(5000, window=777, seed=3))
    assert sum(w.n for w in a) == 5000
    assert a[-1].n == 5000 % 777
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.row, y.row)
        np.testing.assert_array_equal(x.kind, y.kind)


# ---------------------------------------------------------------------------
# campaign stream axis
# ---------------------------------------------------------------------------

def test_campaign_stream_axis_mixed_with_batched():
    from repro.core.campaign import Campaign
    rng = np.random.RandomState(29)
    tr = random_trace(rng, 200, kinds=2, dep_max=2)
    c = Campaign()
    c.add(tr, JETSON_NANO, mode="ts", arm="batch")
    c.add(lambda: traces.iter_windows(tr, 64), JETSON_NANO, mode="ts",
          stream=True, chunk=32, arm="stream")
    c.add(lambda: traces.synthetic_stream(500, window=128, seed=1),
          JETSON_NANO, mode="nots", stream=True, chunk=32, arm="synth")
    recs = c.run()
    assert [r["arm"] for r in recs] == ["batch", "stream", "synth"]
    for k in AGG_KEYS:
        assert int(recs[0][k]) == int(recs[1][k]), k
    assert recs[2]["n_requests"] == 500
    assert c.n_groups() == 3  # batch + two stream groups (modes differ)
    # stream_collect="full" returns exact arrays through the campaign too
    full = c.run(stream_collect="full")
    np.testing.assert_array_equal(
        full[1]["t_resp"], run(tr, JETSON_NANO, "ts")["t_resp"][:tr.n])

    with pytest.raises(ValueError, match="stream=True"):
        c.add([tr], JETSON_NANO)
    with pytest.raises(ValueError, match="stream"):
        c.add(tr, JETSON_NANO, chunk=64)


def test_campaign_extend_mismatch_raises():
    from repro.core.campaign import Campaign
    tr = Trace.of(kind=np.zeros(8), bank=np.zeros(8), row=np.zeros(8),
                  delta=np.zeros(8))
    with pytest.raises(ValueError, match="metas"):
        Campaign().extend([tr, tr], JETSON_NANO, metas=[{}])
