"""Trainer substrate: optimizer, microbatching, checkpoint/restart,
fault tolerance, data pipeline, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.distributed.grad_comp import make_ef_compressor, simple_compressor
from repro.models import model_zoo
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, make_train_step
from tests.conftest import tiny_cfg


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("qwen2_1_5b", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16)
    model = model_zoo.build(cfg, s_max=16)
    return cfg, model


def test_loss_decreases(setup, tmp_path):
    cfg, model = setup
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=1)
    tr = Trainer(model, opt.AdamWConfig(lr=1e-2, warmup=5, total_steps=200),
                 ckpt_dir=str(tmp_path), ckpt_every=20)
    state = tr.init_state()
    state, hist = tr.run(state, iter(ShardedLoader(src)), steps=60, log_every=0)
    assert hist[-1] < hist[0] * 0.85, (hist[0], hist[-1])


def test_checkpoint_resume_exact(setup, tmp_path):
    cfg, model = setup
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=2)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup=2, total_steps=50)

    # run 6 steps straight
    tr = Trainer(model, ocfg, ckpt_dir=None)
    s_ref = tr.init_state(seed=3)
    loader = ShardedLoader(src)
    s_ref, _ = tr.run(s_ref, iter(loader), steps=6, log_every=0)

    # run 3, checkpoint, "crash", restore, run 3 more with aligned data
    d = str(tmp_path / "ck")
    tr2 = Trainer(model, ocfg, ckpt_dir=d, ckpt_every=3)
    s = tr2.init_state(seed=3)
    loader2 = ShardedLoader(src)
    s, _ = tr2.run(s, iter(loader2), steps=3, log_every=0)
    ckpt.save(d, s, int(s.step))
    del s  # crash

    restored = ckpt.restore_latest(d)
    assert restored is not None
    step0 = restored.pop("__step__")
    s2 = ckpt.load_into(restored, tr2.init_state(seed=3))
    loader3 = ShardedLoader(src, start_step=step0)
    s2, _ = tr2.run(s2, iter(loader3), steps=3, log_every=0)

    for a, b in zip(jax.tree_util.tree_leaves(s_ref.master),
                    jax.tree_util.tree_leaves(s2.master)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_checkpoint_crash_safety(setup, tmp_path):
    """A half-written checkpoint (tmp dir) must never be restored."""
    cfg, model = setup
    d = str(tmp_path)
    s = opt.init_state(model.init(jax.random.PRNGKey(0)))
    ckpt.save(d, s, 5)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # simulated crash
    assert ckpt.latest_step(d) == 5


def test_async_checkpoint(setup, tmp_path):
    cfg, model = setup
    s = opt.init_state(model.init(jax.random.PRNGKey(0)))
    th = ckpt.save(str(tmp_path), s, 1, async_=True)
    th.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_microbatch_equivalence(setup):
    """k microbatches must match the monolithic step closely."""
    cfg, model = setup
    ocfg = opt.AdamWConfig(lr=1e-3, warmup=1, total_steps=10, clip_norm=1e9)
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=4)
    batch = src.batch(0)
    s1 = opt.init_state(model.init(jax.random.PRNGKey(1)))
    s2 = jax.tree_util.tree_map(jnp.copy, s1)
    step1 = jax.jit(make_train_step(model, ocfg, num_microbatches=1))
    step2 = jax.jit(make_train_step(model, ocfg, num_microbatches=4))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
    for a, b in zip(jax.tree_util.tree_leaves(s1.master),
                    jax.tree_util.tree_leaves(s2.master)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-1, atol=2e-3)


def test_grad_compression_bounded_error(setup):
    cfg, model = setup
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=5)
    batch = src.batch(0)
    params = model.init(jax.random.PRNGKey(2))
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gc = simple_compressor(g)
    for a, b in zip(jax.tree_util.tree_leaves(g), jax.tree_util.tree_leaves(gc)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-12
        assert float(jnp.max(jnp.abs(a - b))) <= scale / 127.0 + 1e-9

    compress, init_ef = make_ef_compressor()
    ef = init_ef(g)
    total_true = jax.tree_util.tree_map(jnp.zeros_like, g)
    total_sent = jax.tree_util.tree_map(jnp.zeros_like, g)
    for _ in range(8):  # error feedback: accumulated update stays unbiased
        sent, ef = compress(g, ef)
        total_true = jax.tree_util.tree_map(lambda t, x: t + x, total_true, g)
        total_sent = jax.tree_util.tree_map(lambda t, x: t + x, total_sent, sent)
    for t, s, e in zip(jax.tree_util.tree_leaves(total_true),
                       jax.tree_util.tree_leaves(total_sent),
                       jax.tree_util.tree_leaves(ef)):
        np.testing.assert_allclose(np.asarray(t), np.asarray(s + e), rtol=1e-4,
                                   atol=1e-5)


def test_straggler_hook_fires(setup):
    cfg, model = setup
    events = []
    src = SyntheticLM(cfg.vocab_size, 16, 8, seed=6)

    class SlowLoader:
        def __init__(self):
            self.it, self.n = iter(ShardedLoader(src)), 0

        def __iter__(self):
            return self

        def __next__(self):
            import time
            self.n += 1
            if self.n == 9:
                time.sleep(1.0)  # injected straggler
            return next(self.it)

    tr = Trainer(model, opt.AdamWConfig(), straggler_factor=3.0,
                 hooks={"on_straggler": lambda s, dt, med: events.append(s)})
    state = tr.init_state()
    state, _ = tr.run(state, iter(SlowLoader()), steps=10, log_every=0)
    assert tr.straggler_events >= 1 and events


def test_data_determinism():
    src = SyntheticLM(128, 16, 8, seed=7)
    a = src.batch(3)
    b = src.batch(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    h0 = ShardedLoader(src, host_id=0, n_hosts=2)
    h1 = ShardedLoader(src, host_id=1, n_hosts=2)
    b0, b1 = next(iter(h0)), next(iter(h1))
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
