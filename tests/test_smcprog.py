"""The MC-policy VM (`repro.core.smcprog`): assembler validation,
content-addressed digests and cost model, bit-identity of the built-in
FR-FCFS/FCFS programs with the legacy `sys.scheduler` flag, policy
grids through Campaign, behavioral divergence of the built-ins, the
corrected idle-hop behavior, and the fast-scan late-call guard."""
import dataclasses

import numpy as np
import pytest

from repro.core import emulator, smcprog
from repro.core.campaign import Campaign
from repro.core.emulator import BIG, Trace, run, run_many
from repro.core.smcprog import PolicyBuilder, PolicyProgram
from repro.core.techniques import SchedulingPolicyStudy
from repro.core.timescale import JETSON_NANO


def grid_trace(n=45, seed=5):
    """All request kinds (incl. mid-trace NOPs and RowClone ops) and
    random deps — the TestSlotBudget grid workload."""
    rng = np.random.RandomState(seed)
    return Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                    row=rng.randint(0, 4096, n),
                    delta=rng.randint(0, 24, n), dep=rng.randint(0, 3, n))


def bursty_trace(n=120, seed=3, n_banks=4):
    """8-deep request bursts: several requests visible per decision, so
    scheduling policy has real choices."""
    rng = np.random.RandomState(seed)
    delta = np.where(np.arange(n) % 8 == 0, 400, 0)
    row = np.where(rng.rand(n) < 0.6, 7, rng.randint(0, 4096, n))
    return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, n_banks, n),
                    row=row, delta=delta)


class TestAssembler:
    def test_build_and_describe(self):
        b = PolicyBuilder()
        p = b.build(score=b.add(b.score_age(),
                                b.mul(b.mask_bank_busy(), b.const(64))),
                    boost=b.score_row_hit(), name="demo")
        assert p.n_ops == 6
        text = p.describe()
        assert "demo" in text and "score" in text and "boost" in text

    def test_foreign_register_rejected(self):
        b1, b2 = PolicyBuilder(), PolicyBuilder()
        r = b1.score_age()
        with pytest.raises(ValueError, match="not a register"):
            b2.build(score=r)

    def test_validate_rejects_bad_programs(self):
        with pytest.raises(ValueError, match="score_reg"):
            PolicyProgram(table=((smcprog.OP_AGE, 0, 0, 0),),
                          score_reg=3).validate()
        with pytest.raises(ValueError, match="unknown opcode"):
            PolicyProgram(table=((99, 0, 0, 0),), score_reg=0).validate()
        with pytest.raises(ValueError, match="earlier value"):
            # operand references itself (not an earlier SSA value)
            PolicyProgram(table=((smcprog.OP_ADD, 0, 0, 0),),
                          score_reg=0).validate()

    def test_content_addressed_equality(self):
        a = smcprog.frfcfs_program()
        b = dataclasses.replace(smcprog.frfcfs_program(), name="renamed")
        assert a == b and hash(a) == hash(b)   # name is display-only
        assert a.digest == b.digest
        # cost-model fields never enter the emulation: same group too
        c = dataclasses.replace(a, smc_cycles_override=999, base_cycles=1)
        assert a == c and hash(a) == hash(c)
        assert a != smcprog.fcfs_program()
        assert a.digest != smcprog.fcfs_program().digest

    def test_cost_model(self):
        p = smcprog.fcfs_program()
        assert p.smc_cycles() == p.base_cycles + p.cycles_per_op * p.n_ops
        pinned = dataclasses.replace(p, smc_cycles_override=777)
        assert pinned.smc_cycles() == 777
        sysc = JETSON_NANO.with_policy(p)
        assert sysc.policy == p
        assert sysc.smc_cycles_per_decision == p.smc_cycles()
        # attaching without with_policy keeps the config's cost
        kept = dataclasses.replace(JETSON_NANO, policy=p)
        assert kept.smc_cycles_per_decision == \
            JETSON_NANO.smc_cycles_per_decision


class TestBitIdentity:
    """Acceptance: built-in FR-FCFS and FCFS programs are bit-identical
    to the legacy `sys.scheduler` flag across the TestSlotBudget grid —
    responses, issue times, and SMC cycle counters included."""

    @pytest.mark.parametrize("mode,window,sched", [
        ("ts", 1, "frfcfs"), ("nots", 4, "frfcfs"),
        ("reference", 2, "fcfs"), ("ts", 4, "fcfs")])
    def test_program_matches_legacy_flag(self, mode, window, sched):
        tr = grid_trace()
        prog = (smcprog.frfcfs_program() if sched == "frfcfs"
                else smcprog.fcfs_program())
        sys_leg = dataclasses.replace(JETSON_NANO, window=window,
                                      scheduler=sched)
        sys_prog = dataclasses.replace(sys_leg, policy=prog)
        a = run(tr, sys_leg, mode)
        b = run(tr, sys_prog, mode)
        for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
                  "smc_fpga_cycles"):
            assert int(a[k]) == int(b[k]), k
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])

    def test_run_equals_run_many_equals_run_ref(self):
        tr = grid_trace(seed=9)
        sys_prog = dataclasses.replace(JETSON_NANO,
                                       policy=smcprog.frfcfs_program())
        a = run(tr, sys_prog, "ts")
        b = run_many([tr, tr], sys_prog, "ts")[1]
        c = emulator.run_ref(tr, sys_prog, "ts")
        for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
                  "smc_fpga_cycles"):
            assert int(a[k]) == int(b[k]) == int(c[k]), k
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_resp"], c["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], c["t_issue"])

    def test_ts_invariant_to_policy_cost(self):
        """Time scaling hides SMC slowness: deriving the decision cost
        from program length must not move ts results — and must move
        nots results (that is the modeling gap the policy axis opens)."""
        tr = grid_trace(seed=13)
        prog = smcprog.frfcfs_program()
        kept = dataclasses.replace(JETSON_NANO, policy=prog)
        derived = JETSON_NANO.with_policy(prog)
        assert derived.smc_cycles_per_decision != \
            kept.smc_cycles_per_decision
        assert int(run(tr, kept, "ts")["exec_cycles"]) \
            == int(run(tr, derived, "ts")["exec_cycles"])
        slow = JETSON_NANO.with_policy(
            dataclasses.replace(prog, smc_cycles_override=4000))
        assert int(run(tr, slow, "nots")["exec_cycles"]) \
            > int(run(tr, derived, "nots")["exec_cycles"])


class TestPolicyGrid:
    """Acceptance: a grid of >= 4 programs runs through Campaign in one
    batched dispatch per compile-key group (content-addressed)."""

    def test_grid_one_dispatch_per_program(self):
        """The staged-constant (legacy) path: policy_axis=False keeps
        one compile-key group — one compile, one dispatch — per
        program. The runtime-axis default's contract (one group per
        table-length bucket) is pinned in tests/test_policy_axis.py."""
        programs = list(smcprog.builtin_programs().values())
        assert len(programs) >= 4
        trs = [bursty_trace(seed=s) for s in (0, 1)]
        c = Campaign()
        for i, tr in enumerate(trs):
            c.add_policy_grid(tr, JETSON_NANO, programs, i=i,
                              policy_axis=False)
        assert c.n_groups() == len(programs)
        emulator.cache_clear()
        recs = c.run()
        stats = emulator.cache_stats()
        assert stats["misses"] == len(programs)
        assert stats["hits"] == 0
        assert len(recs) == len(programs) * len(trs)
        assert {r["policy"] for r in recs} == {p.name for p in programs}
        for r in recs:
            assert int(r["served"]) == trs[0].n

    def test_same_content_programs_share_group(self):
        fresh1, fresh2 = smcprog.fcfs_program(), dataclasses.replace(
            smcprog.fcfs_program(), name="fcfs-clone")
        tr = bursty_trace(seed=2)
        c = (Campaign()
             .add(tr, dataclasses.replace(JETSON_NANO, policy=fresh1))
             .add(tr, dataclasses.replace(JETSON_NANO, policy=fresh2)))
        assert c.n_groups() == 1
        r = c.run()
        assert int(r[0]["exec_cycles"]) == int(r[1]["exec_cycles"])

    def test_duplicate_names_rejected(self):
        """Grid records key on program names: two distinct programs
        under one (e.g. the default) name would silently collide."""
        b1, b2 = PolicyBuilder(), PolicyBuilder()
        progs = [b1.build(score=b1.score_age()),
                 b2.build(score=b2.score_row_hit())]
        # ValueError, not AssertionError: the guard must survive python -O
        with pytest.raises(ValueError, match="unique"):
            Campaign().add_policy_grid(bursty_trace(), JETSON_NANO, progs)
        with pytest.raises(ValueError, match="unique"):
            SchedulingPolicyStudy(JETSON_NANO, programs=progs)

    def test_policy_study(self):
        study = SchedulingPolicyStudy(
            dataclasses.replace(JETSON_NANO, window=8))
        out = study.evaluate_traces([bursty_trace()])
        assert len(out) == 1
        d = out[0]
        assert set(d) == set(smcprog.builtin_programs())
        assert d["frfcfs"]["speedup_vs_baseline"] == 1.0
        assert d["bank-rr"]["smc_cycles"] > d["fcfs"]["smc_cycles"]


class TestBuiltinBehaviors:
    """The built-ins must actually schedule differently on traffic with
    visible-queue choices (bursty, hot-row, multi-bank)."""

    def _run(self, prog, tr):
        # with_policy on the window-8 base: same compile keys as the
        # SchedulingPolicyStudy points, so these tests share executables
        sysc = dataclasses.replace(JETSON_NANO, window=8).with_policy(prog)
        return run(tr, sysc, "ts")

    def test_frfcfs_harvests_more_hits_than_fcfs(self):
        tr = bursty_trace()
        fr = self._run(smcprog.frfcfs_program(), tr)
        fc = self._run(smcprog.fcfs_program(), tr)
        assert int(fr["row_hits"]) > int(fc["row_hits"])
        assert int(fr["exec_cycles"]) <= int(fc["exec_cycles"])

    def test_closed_page_sheds_hits(self):
        tr = bursty_trace()
        fr = self._run(smcprog.frfcfs_program(), tr)
        cp = self._run(smcprog.closed_page_program(), tr)
        assert int(cp["row_hits"]) < int(fr["row_hits"])

    def test_all_builtins_complete(self):
        tr = bursty_trace(seed=11)
        for p in smcprog.builtin_programs().values():
            r = self._run(p, tr)
            assert int(r["served"]) == tr.n, p.name
            assert (np.asarray(r["t_resp"])[:tr.n] < int(BIG)).all(), p.name


class TestIdleHopFix:
    """Re-baselined mid-trace NOP behavior: the idle hop is skipped on
    an empty hardware queue, so a NOP run no longer saturates
    mc_release and poisons later responses."""

    def test_mid_trace_nops_fully_served(self):
        rng = np.random.RandomState(7)
        n = 60
        kind = rng.randint(0, 2, n)
        kind[10:18] = 4
        kind[30:33] = 4
        tr = Trace.of(kind=kind, bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(0, 6, n), dep=rng.randint(0, 2, n))
        real = kind != 4
        a = run(tr, JETSON_NANO, "ts")
        assert int(a["served"]) == int(real.sum())
        assert (np.asarray(a["t_resp"])[:n][real] < int(BIG)).all()
        # both engines carry the fix identically
        b = emulator.run_ref(tr, JETSON_NANO, "ts")
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])


class TestFastScanGuard:
    def test_config_layer_import_leaves_backend_down(self):
        """timescale.py imports smcprog: neither may create device
        constants at import time, or enable_fast_cpu_scan() (which now
        raises when late) could never follow a config import."""
        import os
        import subprocess
        import sys as _sys
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
        env["PYTHONPATH"] = os.path.join(root, "src")
        code = ("from repro.core.timescale import JETSON_NANO\n"
                "from repro.utils.jax_compat import enable_fast_cpu_scan\n"
                "assert enable_fast_cpu_scan() is True\n")
        proc = subprocess.run([_sys.executable, "-c", code], cwd=root,
                              capture_output=True, text=True, env=env)
        assert proc.returncode == 0, proc.stderr

    def test_late_call_raises(self, monkeypatch):
        import jax.numpy as jnp
        from repro.utils import jax_compat
        jnp.zeros(1).block_until_ready()  # backend definitely up
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        with pytest.raises(RuntimeError, match="after the JAX backend"):
            jax_compat.enable_fast_cpu_scan()

    def test_operator_pinned_flag_respected(self, monkeypatch):
        from repro.utils import jax_compat
        monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_use_thunk_runtime=false")
        assert jax_compat.enable_fast_cpu_scan() is True
        monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_use_thunk_runtime=true")
        with pytest.warns(UserWarning, match="30x slower"):
            assert jax_compat.enable_fast_cpu_scan() is False
