"""EasyDRAM engine behaviour: time-scaling validation (Sec. 6), causality,
scheduler policy effects, DRAM timing invariants."""
import dataclasses

import numpy as np
import pytest

from repro.core import dram, emulator
from repro.core.emulator import BIG, Trace, run
from repro.core.timescale import JETSON_NANO, PIDRAM_LIKE, SystemConfig


def chase(n=500, seed=0, banks=16, rows=4096):
    rng = np.random.RandomState(seed)
    return Trace.of(kind=np.zeros(n), bank=rng.randint(0, banks, n),
                    row=rng.randint(0, rows, n),
                    delta=np.full(n, 4), dep=np.ones(n))


def stream(n=500, delta=4):
    i = np.arange(n)
    return Trace.of(kind=np.zeros(n), bank=i % 16, row=(i // 16) % 4096,
                    delta=np.full(n, delta))


class TestTimeScalingValidation:
    """The paper's Sec. 6 claim: time-scaled execution time matches the
    reference system (HW MC at the modeled clock) to <0.1%; here the
    engine is deterministic so the match is exact, and the substantive
    assertions are the invariances behind the claim."""

    def test_ts_equals_reference(self):
        for tr in (chase(), stream()):
            a = run(tr, JETSON_NANO, "ts")
            b = run(tr, JETSON_NANO, "reference")
            assert int(a["exec_cycles"]) == int(b["exec_cycles"])

    def test_ts_invariant_to_fpga_clocks(self):
        tr = chase()
        base = None
        for smc in (50, 400, 3000, 20000):
            for fmc in (50.0, 100.0, 200.0):
                sysc = dataclasses.replace(JETSON_NANO,
                                           smc_cycles_per_decision=smc,
                                           f_mc_fpga_mhz=fmc)
                e = int(run(tr, sysc, "ts")["exec_cycles"])
                base = base or e
                assert e == base, (smc, fmc)

    def test_nots_depends_on_smc_speed(self):
        tr = chase()
        slow = int(run(dataclasses.replace(JETSON_NANO, smc_cycles_per_decision=4000),
                       tr and tr, "nots")["exec_cycles"]) \
            if False else int(run(tr, dataclasses.replace(
                JETSON_NANO, smc_cycles_per_decision=4000), "nots")["exec_cycles"])
        fast = int(run(tr, dataclasses.replace(
            JETSON_NANO, smc_cycles_per_decision=50), "nots")["exec_cycles"])
        assert slow > 1.5 * fast

    def test_validation_error_band(self):
        """Headline number: avg + max error across the workload suite."""
        errs = []
        for seed in range(6):
            tr = chase(300, seed)
            a = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
            b = int(run(tr, JETSON_NANO, "reference")["exec_cycles"])
            errs.append(abs(a - b) / b)
        assert np.mean(errs) < 1e-3 and np.max(errs) < 1e-2  # paper: <0.1% / <1%


class TestEngineInvariants:
    def test_causality_and_completion(self):
        tr = chase(400, 3)
        r = run(tr, JETSON_NANO, "ts")
        assert int(r["served"]) == tr.n
        resp, iss = r["t_resp"][:tr.n], r["t_issue"][:tr.n]
        assert (resp < int(BIG)).all()
        assert (resp > iss).all()

    def test_dependent_slower_than_independent(self):
        dep = chase(400)
        ind = Trace.of(dep.kind, dep.bank, dep.row, dep.delta)  # dep=0
        a = int(run(dep, JETSON_NANO, "ts")["exec_cycles"])
        b = int(run(ind, JETSON_NANO, "ts")["exec_cycles"])
        assert a > b

    def test_row_hits_speed_up(self):
        same_row = Trace.of(np.zeros(400), np.zeros(400), np.zeros(400),
                            np.full(400, 2))
        diff_row = Trace.of(np.zeros(400), np.zeros(400),
                            np.arange(400) % 4096, np.full(400, 2))
        a = run(same_row, JETSON_NANO, "ts")
        b = run(diff_row, JETSON_NANO, "ts")
        assert int(a["row_hits"]) > int(b["row_hits"])
        assert int(a["exec_cycles"]) < int(b["exec_cycles"])

    def test_frfcfs_beats_fcfs_on_mixed_traffic(self):
        rng = np.random.RandomState(1)
        n = 600
        row = np.where(rng.rand(n) < 0.7, 7, rng.randint(0, 4096, n))
        tr = Trace.of(np.zeros(n), np.zeros(n), row, np.full(n, 1))
        fr = run(tr, JETSON_NANO, "ts")
        fc = run(tr, dataclasses.replace(JETSON_NANO, scheduler="fcfs"), "ts")
        assert int(fr["exec_cycles"]) <= int(fc["exec_cycles"])
        assert int(fr["row_hits"]) >= int(fc["row_hits"])

    def test_trace_padding_neutral(self):
        tr = chase(300)
        a = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
        b = int(run(emulator.pad_trace(tr, 1024), JETSON_NANO, "ts")["exec_cycles"])
        assert a == b


class TestDramTimings:
    def test_row_miss_slower_than_hit(self):
        t = dram.Timing()
        bs = dram.init_bank_state(dram.Geometry())
        bs, t1, hit1 = dram.service_request(bs, t, dram.READ, 0, 5, 0, t.tRCD)
        assert not bool(hit1)
        bs, t2, hit2 = dram.service_request(bs, t, dram.READ, 0, 5, int(t1), t.tRCD)
        assert bool(hit2)
        assert int(t2) - int(t1) < int(t1)

    def test_reduced_trcd_faster(self):
        t = dram.Timing()
        g = dram.Geometry()
        b1, d1, _ = dram.service_request(dram.init_bank_state(g), t, dram.READ,
                                         0, 5, 0, t.tRCD)
        b2, d2, _ = dram.service_request(dram.init_bank_state(g), t, dram.READ,
                                         0, 5, 0, t.tRCD_reduced)
        assert int(d2) == int(d1) - (t.tRCD - t.tRCD_reduced)

    def test_banks_pipeline(self):
        """Streaming across banks must beat hammering one bank."""
        n = 256
        multi = Trace.of(np.zeros(n), np.arange(n) % 16, (np.arange(n) // 16) % 4096,
                         np.full(n, 1))
        single = Trace.of(np.zeros(n), np.zeros(n), np.arange(n) % 4096,
                          np.full(n, 1))
        a = int(run(multi, JETSON_NANO, "ts")["exec_cycles"])
        b = int(run(single, JETSON_NANO, "ts")["exec_cycles"])
        assert a < b
