"""Batched campaigns (`run_many` / `Campaign`) vs the single-trace path:
bit-exactness, Sec. 6 invariants under batching, compile-cache behavior."""
import numpy as np
import pytest

from repro.core import emulator
from repro.core.bloom import BloomFilter
from repro.core.campaign import Campaign
from repro.core.emulator import Trace, run, run_many
from repro.core.timescale import JETSON_NANO


def mixed_traces(n_traces=4, base=70, seed=3):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n_traces):
        n = base + 17 * i  # varied lengths, one 128 bucket
        out.append(Trace.of(kind=rng.randint(0, 2, n),
                            bank=rng.randint(0, 16, n),
                            row=rng.randint(0, 4096, n),
                            delta=rng.randint(1, 8, n),
                            dep=rng.randint(0, 2, n)))
    return out


def small_bloom(seed=0, m_bits=1 << 14, k=3):
    rng = np.random.RandomState(seed)
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 200).astype(np.uint32),
                           m_bits=m_bits, k=k)
    return (bf.bits, bf.k, bf.m_bits)


class TestRunManyExactness:
    def test_matches_per_trace_run(self):
        trs = mixed_traces()
        batch = run_many(trs, JETSON_NANO, "ts")
        for tr, b in zip(trs, batch):
            s = run(tr, JETSON_NANO, "ts")
            assert int(b["exec_cycles"]) == int(s["exec_cycles"])
            assert int(b["row_hits"]) == int(s["row_hits"])
            np.testing.assert_array_equal(b["t_resp"], s["t_resp"])
            np.testing.assert_array_equal(b["t_issue"], s["t_issue"])
            assert b["avg_load_latency_cycles"] == s["avg_load_latency_cycles"]

    def test_matches_with_shared_bloom(self):
        trs = mixed_traces(3)
        bloom = small_bloom()
        batch = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        for tr, b in zip(trs, batch):
            s = run(tr, JETSON_NANO, "ts", bloom=bloom)
            assert int(b["exec_cycles"]) == int(s["exec_cycles"])
            np.testing.assert_array_equal(b["t_resp"], s["t_resp"])

    def test_stacked_blooms_match_shared(self):
        """Per-trace filter stacking: identical filters per trace must
        reproduce the shared-broadcast result bit-for-bit."""
        trs = mixed_traces(3)
        bloom = small_bloom()
        shared = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        stacked = run_many(trs, JETSON_NANO, "ts", blooms=[bloom] * len(trs))
        for a, b in zip(shared, stacked):
            assert int(a["exec_cycles"]) == int(b["exec_cycles"])
            np.testing.assert_array_equal(a["t_resp"], b["t_resp"])

    def test_results_in_input_order(self):
        trs = mixed_traces(4)
        batch = run_many(trs, JETSON_NANO, "ts")
        singles = [run(tr, JETSON_NANO, "ts") for tr in trs]
        assert [int(b["exec_cycles"]) for b in batch] \
            == [int(s["exec_cycles"]) for s in singles]


class TestBatchedInvariants:
    def test_ts_equals_reference_inside_one_batch(self):
        """Sec. 6: the time-scaled result must coincide with the RTL
        reference — including when both arms run inside one batched
        campaign across ts/nots/reference and bloom arms."""
        trs = mixed_traces(2, base=80, seed=5)
        bloom = small_bloom(1)
        c = Campaign()
        for i, tr in enumerate(trs):
            for mode in ("ts", "reference", "nots"):
                c.add(tr, JETSON_NANO, mode=mode, i=i, arm="plain")
            for mode in ("ts", "reference"):
                c.add(tr, JETSON_NANO, mode=mode, bloom=bloom, i=i, arm="bloom")
        recs = c.run()
        by = {(r["i"], r["arm"], r["mode"]): int(r["exec_cycles"])
              for r in recs}
        for i in range(len(trs)):
            assert by[(i, "plain", "ts")] == by[(i, "plain", "reference")]
            assert by[(i, "bloom", "ts")] == by[(i, "bloom", "reference")]
            # nots leaks FPGA-platform slowness -> must differ from ts
            assert by[(i, "plain", "nots")] != by[(i, "plain", "ts")]

    def test_per_trace_modes_in_run_many(self):
        trs = mixed_traces(2)
        out = run_many(trs + trs, JETSON_NANO,
                       mode=["ts", "ts", "reference", "reference"])
        assert int(out[0]["exec_cycles"]) == int(out[2]["exec_cycles"])
        assert int(out[1]["exec_cycles"]) == int(out[3]["exec_cycles"])
        assert out[2]["mode"] == "reference"


class TestCompileCache:
    def test_second_same_shaped_batch_hits_cache(self):
        trs = mixed_traces(4, seed=11)
        run_many(trs, JETSON_NANO, "ts")  # populate
        before = emulator.cache_stats()
        # same shapes, different contents -> must NOT recompile
        trs2 = mixed_traces(4, seed=12)
        run_many(trs2, JETSON_NANO, "ts")
        after = emulator.cache_stats()
        assert after["misses"] == before["misses"]
        assert after["hits"] == before["hits"] + 1

    def test_batch_axis_padding_shares_executable(self):
        """3 traces pad the batch axis to 4: same executable as a
        4-trace batch of the same bucket."""
        run_many(mixed_traces(4, seed=13), JETSON_NANO, "ts")
        before = emulator.cache_stats()
        out = run_many(mixed_traces(3, seed=14), JETSON_NANO, "ts")
        after = emulator.cache_stats()
        assert len(out) == 3
        assert after["misses"] == before["misses"]

    def test_campaign_group_count(self):
        trs = mixed_traces(3)
        bloom = small_bloom()
        c = Campaign()
        for tr in trs:
            c.add(tr, JETSON_NANO, mode="ts")
            c.add(tr, JETSON_NANO, mode="ts", bloom=bloom)
            c.add(tr, JETSON_NANO, mode="nots")
        # one group per (bucket, sys, mode, bloom-shape)
        assert c.n_groups() == 3

    def test_mixed_ts_reference_share_group(self):
        """'reference' compiles to the 'ts' program, so mixing the two
        in one campaign is a single compile group — and each record
        still reports its own mode."""
        tr = mixed_traces(1)[0]
        c = (Campaign().add(tr, JETSON_NANO, mode="ts")
                       .add(tr, JETSON_NANO, mode="reference"))
        assert c.n_groups() == 1
        r = c.run()
        assert int(r[0]["exec_cycles"]) == int(r[1]["exec_cycles"])
        assert r[0]["mode"] == "ts" and r[1]["mode"] == "reference"


class TestSlotBudget:
    """Exact per-group scan budgets + the lowered bucket floor: the
    engine must spend slots proportional to real work, and stay
    bit-identical to the uniform-budget reference engine."""

    def test_bucket_floor_lowered(self):
        assert emulator._bucket(1) == 32
        assert emulator._bucket(8) == 32
        assert emulator._bucket(32) == 32
        assert emulator._bucket(33) == 64
        assert emulator._bucket(300) == 512  # unchanged above the floor

    def test_budget_formula(self):
        # full bucket of real requests degenerates to the uniform budget
        assert emulator.slot_budget(512, 512) == 2 * 512 + 4
        # an 8-request trace no longer burns 2*256+4 = 516 slots
        assert emulator.slot_budget(emulator._bucket(8), 8) <= 40
        # monotone in n_real and capped by the degenerate budget
        buds = [emulator.slot_budget(256, r) for r in range(0, 257, 8)]
        assert buds == sorted(buds)
        assert buds[-1] == 2 * 256 + 4

    def test_small_trace_matches_reference(self):
        rng = np.random.RandomState(2)
        tr = Trace.of(kind=np.zeros(8), bank=rng.randint(0, 16, 8),
                      row=rng.randint(0, 4096, 8), delta=np.full(8, 3),
                      dep=np.ones(8))
        a = run(tr, JETSON_NANO, "ts")
        b = emulator.run_ref(tr, JETSON_NANO, "ts")
        assert int(a["exec_cycles"]) == int(b["exec_cycles"])
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])

    @pytest.mark.parametrize("n", [31, 32, 33, 64, 65])
    def test_bucket_boundaries_match_reference(self, n):
        rng = np.random.RandomState(n)
        tr = Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(1, 8, n), dep=rng.randint(0, 2, n))
        a = run(tr, JETSON_NANO, "ts")
        b = emulator.run_ref(tr, JETSON_NANO, "ts")
        for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
                  "smc_fpga_cycles"):
            assert int(a[k]) == int(b[k]), k
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])

    def test_mid_trace_nops_match_reference(self):
        """NOP runs inside the trace (not just padding) stress the
        frontier's NOP resolution and the budget's sufficiency
        accounting. Re-baselined in PR 4 to the corrected idle-hop
        behavior: the idle hop is skipped while the hardware queue is
        empty (both engines changed together), so a NOP run that drains
        the queue no longer saturates mc_release to BIG-1 — every real
        request now completes with a sane response tag, and the two
        engines must still agree bit-for-bit."""
        rng = np.random.RandomState(7)
        n = 60
        kind = rng.randint(0, 2, n)
        kind[10:18] = 4   # 8 consecutive NOPs
        kind[30:33] = 4
        tr = Trace.of(kind=kind, bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(0, 6, n), dep=rng.randint(0, 2, n))
        a = run(tr, JETSON_NANO, "ts")
        b = emulator.run_ref(tr, JETSON_NANO, "ts")
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])
        assert int(a["served"]) == int(b["served"])
        # corrected behavior: no response poisoning, everything serves
        real = kind != 4
        assert int(a["served"]) == int(real.sum())
        assert (np.asarray(a["t_resp"])[:n][real] < int(emulator.BIG)).all()

    @pytest.mark.parametrize("mode,window,sched", [
        ("ts", 1, "frfcfs"), ("nots", 4, "frfcfs"),
        ("reference", 2, "fcfs"), ("ts", 4, "fcfs")])
    def test_modes_and_configs_match_reference(self, mode, window, sched):
        """Deterministic slice of the hypothesis property (which is
        skipped when hypothesis is absent): mode x window x scheduler
        bit-identity between the budgeted fast core and the reference."""
        import dataclasses
        rng = np.random.RandomState(5)
        n = 45
        tr = Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(0, 24, n), dep=rng.randint(0, 3, n))
        sysc = dataclasses.replace(JETSON_NANO, window=window,
                                   scheduler=sched)
        a = run(tr, sysc, mode)
        b = emulator.run_ref(tr, sysc, mode)
        for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
                  "smc_fpga_cycles"):
            assert int(a[k]) == int(b[k]), k
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
        np.testing.assert_array_equal(a["t_issue"], b["t_issue"])

    def test_bloom_arm_matches_reference(self):
        rng = np.random.RandomState(9)
        n = 64
        bloom = small_bloom(4)
        tr = Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n), delta=rng.randint(1, 8, n),
                      dep=rng.randint(0, 2, n))
        a = run(tr, JETSON_NANO, "ts", bloom=bloom)
        b = emulator.run_ref(tr, JETSON_NANO, "ts", bloom=bloom)
        assert int(a["exec_cycles"]) == int(b["exec_cycles"])
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])

    def test_budget_in_compile_key_stays_consistent(self):
        """Identical trace shapes must keep hitting one executable; the
        budget quantization must not fork cache entries for same-shape
        reruns of the same point."""
        rng = np.random.RandomState(21)
        tr = Trace.of(kind=np.zeros(40), bank=rng.randint(0, 16, 40),
                      row=rng.randint(0, 4096, 40), delta=np.full(40, 2))
        run(tr, JETSON_NANO, "ts")
        before = emulator.cache_stats()
        run(tr, JETSON_NANO, "ts")
        run_many([tr], JETSON_NANO, "ts")
        after = emulator.cache_stats()
        assert after["misses"] == before["misses"]
        assert after["hits"] == before["hits"] + 2

    def test_group_budget_covers_shorter_members(self):
        """A batch group's budget comes from its largest member; the
        shorter members (more padding NOPs than the budget's pad term
        assumes real) must still complete and match their solo runs."""
        short = Trace.of(kind=np.zeros(33), bank=np.arange(33) % 16,
                         row=np.arange(33), delta=np.full(33, 2))
        long = Trace.of(kind=np.zeros(64), bank=np.arange(64) % 16,
                        row=np.arange(64) % 4096, delta=np.full(64, 2))
        assert emulator._bucket(short.n) == emulator._bucket(long.n)
        batch = run_many([short, long], JETSON_NANO, "ts")
        for tr, b in zip((short, long), batch):
            s = run(tr, JETSON_NANO, "ts")
            assert int(b["exec_cycles"]) == int(s["exec_cycles"])
            assert int(b["served"]) == tr.n


class TestApiEdges:
    def test_extend_rejects_short_metas(self):
        c = Campaign()
        # ValueError, not AssertionError: the guard survives python -O
        # and reports both lengths
        with pytest.raises(ValueError, match="metas \\(1\\).*traces \\(3\\)"):
            c.extend(mixed_traces(3), JETSON_NANO, metas=[{"a": 1}])
        assert len(c) == 0  # nothing silently added

    def test_meta_cannot_shadow_result_fields(self):
        c = Campaign()
        c.add(mixed_traces(1)[0], JETSON_NANO, exec_cycles=0)
        # ValueError, not AssertionError: the guard survives python -O
        with pytest.raises(ValueError, match="shadow"):
            c.run()

    def test_list_typed_shared_bloom_broadcasts(self):
        """Shared-vs-per-trace bloom dispatch is by content, not
        container type: a list-typed (words, k, m) still broadcasts."""
        trs = mixed_traces(2)
        bloom = small_bloom()
        a = run_many(trs, JETSON_NANO, "ts", blooms=bloom)
        b = run_many(trs, JETSON_NANO, "ts", blooms=list(bloom))
        for x, y in zip(a, b):
            assert int(x["exec_cycles"]) == int(y["exec_cycles"])
        s = run(trs[0], JETSON_NANO, "ts", bloom=list(bloom))
        assert int(s["exec_cycles"]) == int(a[0]["exec_cycles"])

    def test_campaign_list_typed_bloom(self):
        tr = mixed_traces(1)[0]
        bloom = small_bloom()
        c = (Campaign().add(tr, JETSON_NANO, bloom=bloom)
                       .add(tr, JETSON_NANO, bloom=list(bloom)))
        assert c.n_groups() == 1  # same filter shape -> one group
        r = c.run()
        assert int(r[0]["exec_cycles"]) == int(r[1]["exec_cycles"])

    def test_tuple_of_per_trace_blooms_stacks(self):
        trs = mixed_traces(3)
        blooms = tuple(small_bloom(seed) for seed in range(3))
        stacked = run_many(trs, JETSON_NANO, "ts", blooms=blooms)
        for tr, bf, r in zip(trs, blooms, stacked):
            single = run(tr, JETSON_NANO, "ts", bloom=bf)
            assert int(single["exec_cycles"]) == int(r["exec_cycles"])
