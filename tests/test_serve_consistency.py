"""Decode-path correctness: prefill + single-token decode must reproduce
the full-sequence forward logits (this cross-validates the chunked
mamba/rwkv algebra against their O(1) recurrent decode forms)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, SSMConfig
from repro.models import model_zoo
from repro.models import transformer as tf
from repro.serve.engine import pad_cache_to
from tests.conftest import tiny_cfg

CASES = {
    "qwen3_8b": {},
    "gemma_7b": {},
    "jamba_v0_1_52b": {"n_layers": 8,
                       "moe": MoEConfig(n_experts=4, top_k=2, d_ff=128, every=2,
                                        capacity_factor=8.0),
                       "ssm": SSMConfig(d_state=8, d_conv=4, expand=2, chunk=8)},
    "rwkv6_3b": {"n_heads": 4, "n_kv_heads": 4, "ssm": SSMConfig(chunk=8)},
}


def full_logits(model, cfg, params, tokens):
    x = tf.embed_tokens(params, cfg, tokens)
    positions = jnp.arange(x.shape[1])
    h, _ = tf.forward_train(params, cfg, x, positions, remat=False)
    return tf.logits_from_hidden(params, cfg, h)


@pytest.mark.parametrize("arch", sorted(CASES))
def test_prefill_decode_matches_full_forward(arch, rng):
    cfg = tiny_cfg(arch, **CASES[arch])
    S0, steps = 16, 4
    S = S0 + steps
    model = model_zoo.build(cfg, s_max=S)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)

    ref = full_logits(model, cfg, params, tokens)          # [1,S,V]

    logits, cache = model.prefill_fn(params, {"tokens": tokens[:, :S0]})
    cache = pad_cache_to(cache, S)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(ref[0, S0 - 1]), rtol=2e-2, atol=2e-2)
    for t in range(steps):
        logits, cache = model.decode_fn(params, cache,
                                        tokens[:, S0 + t:S0 + t + 1],
                                        jnp.int32(S0 + t))
        np.testing.assert_allclose(np.asarray(logits[0, -1]),
                                   np.asarray(ref[0, S0 + t]),
                                   rtol=2e-2, atol=2e-2)


def test_whisper_prefill_decode(rng):
    cfg = tiny_cfg("whisper_base", n_enc_layers=2, n_frames=16, n_kv_heads=4)
    from repro.models import encdec as ed
    S0, steps = 8, 3
    S = S0 + steps
    model = model_zoo.build(cfg, s_max=S)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)
    frames = jax.random.normal(rng, (1, cfg.n_frames, cfg.d_model))

    enc = ed.encode(params, cfg, frames)
    h = ed.decode_train(params, cfg, tokens, enc, remat=False)
    ref = ed.logits(params, cfg, h)

    logits, cache = model.prefill_fn(params, {"tokens": tokens[:, :S0],
                                              "frames": frames})
    cache = dict(cache)
    for kk in ("self_k", "self_v"):
        pad = [(0, 0)] * 5
        pad[2] = (0, S - S0)
        cache[kk] = jnp.pad(cache[kk], pad)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(ref[0, S0 - 1]), rtol=2e-2, atol=2e-2)
    for t in range(steps):
        logits, cache = model.decode_fn(params, cache,
                                        tokens[:, S0 + t:S0 + t + 1],
                                        jnp.int32(S0 + t))
        np.testing.assert_allclose(np.asarray(logits[0, -1]),
                                   np.asarray(ref[0, S0 + t]),
                                   rtol=2e-2, atol=2e-2)
