"""Deterministic fault injection (PR 8): the flip set must be a pure
function of (FaultModel.seed, trace content, system config) — bit-identical
across the fast scan core, the reference core, batched ``run_many``,
the streaming window driver, serial vs overlapped campaign execution and
the forced-shard path — and ``faults=None`` must leave compile keys and
results exactly as they were before the fault subsystem existed.

Compile budget note: every distinct (SystemConfig, batch-bucket) pair
costs a fresh XLA compile of the whole scan (~tens of seconds on the
no-fast-scan test runtime), so this module reuses ONE fault config and
ONE trace everywhere and leans on the Python-level reference engine
(no compile) for seed-sensitivity checks.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import emulator, smcprog, traces
from repro.core.campaign import Campaign
from repro.core.emulator import run, run_many, run_ref, run_stream
from repro.core.faults import FaultModel
from repro.core.timescale import JETSON_NANO

GEO = JETSON_NANO.geometry

FM = FaultModel(seed=3, hammer_threshold=8, hammer_flip_fp=30000,
                weak_fp=16000, retention_ticks=30, victim_slots=16)
SYS = JETSON_NANO.with_faults(FM)

FAULT_SCALARS = ("flips", "ham_flips", "ret_flips", "mitigations")
FAULT_LOGS = ("victim_bank", "victim_row", "victim_t")


def hammer_trace(n=96, seed=5):
    return traces.rowhammer_trace(n, GEO, intensity=0.75, seed=seed)


@pytest.fixture(scope="module")
def fault_runs():
    """Every engine over the SAME (trace, fault model) — computed once
    for the whole module (three compiles: single, batch-of-2, stream)."""
    tr = hammer_trace()
    return {
        "tr": tr,
        "fast": run(tr, SYS, "ts"),
        "ref": run_ref(tr, SYS, "ts"),
        "many": run_many([tr, tr], SYS, "ts"),
        "stream": run_stream(tr, SYS, "ts", chunk=32),
    }


def assert_fault_fields_equal(a, b):
    for k in FAULT_SCALARS:
        assert int(a[k]) == int(b[k]), k
    for k in FAULT_LOGS:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert float(a["bit_error_rate"]) == float(b["bit_error_rate"])


class TestEngineInvariance:
    def test_flips_actually_happen(self, fault_runs):
        """The shared config must exercise BOTH error processes, or the
        equality assertions below would pass vacuously."""
        r = fault_runs["fast"]
        assert int(r["ham_flips"]) > 0
        assert int(r["ret_flips"]) > 0
        assert int(r["flips"]) == int(r["ham_flips"]) + int(r["ret_flips"])
        assert 0 < float(r["bit_error_rate"]) <= 1.0
        # the bounded log holds real events: valid banks/rows, -1 padding
        vb = np.asarray(r["victim_bank"])
        filled = vb >= 0
        assert filled.sum() == min(int(r["flips"]), FM.victim_slots)
        assert (np.asarray(r["victim_row"])[filled] >= 0).all()

    def test_fast_matches_reference(self, fault_runs):
        assert_fault_fields_equal(fault_runs["fast"], fault_runs["ref"])

    def test_run_many_matches_and_batch_rows_identical(self, fault_runs):
        a, b = fault_runs["many"]
        assert_fault_fields_equal(a, fault_runs["fast"])
        assert_fault_fields_equal(a, b)  # same trace twice -> same flips

    def test_stream_matches_single_shot(self, fault_runs):
        """The fault carry rides the window shift untouched: the final
        window's state IS the whole stream's record."""
        assert_fault_fields_equal(fault_runs["stream"], fault_runs["fast"])
        assert int(fault_runs["stream"]["exec_cycles"]) == \
            int(fault_runs["fast"]["exec_cycles"])

    def test_campaign_serial_overlapped_sharded_identical(self, fault_runs):
        """The property the resumable-campaign layer depends on: however
        the grid executes, fault results are bit-identical."""
        tr = fault_runs["tr"]

        def build():
            c = Campaign()
            c.add(tr, SYS, arm=0)
            c.add(tr, SYS, arm=1)  # same group: batch bucket of 2
            return c

        a = build().run(serial=True)
        b = build().run(serial=False)
        old = emulator.set_sharding("force")
        try:
            c = build().run()
        finally:
            emulator.set_sharding(old)
        for recs in (b, c):
            for x, y in zip(a, recs):
                assert_fault_fields_equal(x, y)
                assert int(x["exec_cycles"]) == int(y["exec_cycles"])

    def test_seed_sensitivity_via_reference_engine(self, fault_runs):
        """Different seed => different flip set; same seed (fresh run)
        => identical. Uses the reference engine only: no extra compile."""
        tr = fault_runs["tr"]
        again = run_ref(tr, SYS, "ts")
        assert_fault_fields_equal(again, fault_runs["ref"])
        other = run_ref(tr, JETSON_NANO.with_faults(
            dataclasses.replace(FM, seed=FM.seed + 1)), "ts")
        same_log = np.array_equal(np.asarray(other["victim_row"]),
                                  np.asarray(fault_runs["ref"]["victim_row"]))
        assert int(other["flips"]) != int(fault_runs["ref"]["flips"]) \
            or not same_log


class TestZeroCostOff:
    def test_faults_fork_group_keys(self, fault_runs):
        tr = fault_runs["tr"]
        assert emulator.group_key(tr.n, SYS, "ts", None) != \
            emulator.group_key(tr.n, JETSON_NANO, "ts", None)
        assert JETSON_NANO.faults is None

    def test_off_results_have_no_fault_fields_and_timing_matches(
            self, fault_runs):
        """faults=None results carry no fault keys, and — without a
        mitigating policy — fault modeling never perturbs scheduling:
        exec_cycles match exactly."""
        tr = fault_runs["tr"]
        off = run(tr, JETSON_NANO, "ts")
        assert "flips" not in off and "bit_error_rate" not in off
        assert int(off["exec_cycles"]) == \
            int(fault_runs["fast"]["exec_cycles"])
        np.testing.assert_array_equal(off["t_resp"],
                                      fault_runs["fast"]["t_resp"])

    def test_with_faults_validates(self):
        with pytest.raises(ValueError, match="victim_slots"):
            JETSON_NANO.with_faults(dataclasses.replace(FM, victim_slots=0))
        with pytest.raises(ValueError, match="hammer_flip_fp"):
            FaultModel(hammer_flip_fp=65537).validate()
        with pytest.raises(ValueError, match="retention_ticks"):
            FaultModel(retention_ticks=-1).validate()
        assert JETSON_NANO.with_faults(None).faults is None


class TestMitigationPolicies:
    def test_trr_program_suppresses_flips_both_engines(self, fault_runs):
        """Counter-based TRR with a trigger below the hammer threshold
        must drive hammer flips to zero, cost >0 mitigations and slow
        the bank down — identically in both engine cores (one compile)."""
        tr = fault_runs["tr"]
        fm = dataclasses.replace(FM, weak_fp=0)  # isolate the hammer arm
        prog = smcprog.mitigation_programs(trr_threshold=4)["trr4"]
        sysm = dataclasses.replace(
            JETSON_NANO, policy=prog).with_faults(fm)
        fast = run(tr, sysm, "ts")
        ref = run_ref(tr, sysm, "ts")
        assert_fault_fields_equal(fast, ref)
        assert int(fast["ham_flips"]) == 0
        assert int(fast["mitigations"]) > 0
        base = fault_runs["fast"]
        assert int(fast["exec_cycles"]) > 0
        # mitigation charges neighbor-refresh ticks: never faster than
        # the unmitigated run of the same trace
        assert int(fast["exec_cycles"]) >= int(base["exec_cycles"])

    def test_mitigation_program_set(self):
        progs = smcprog.mitigation_programs(para_fp=700, trr_threshold=9)
        assert set(progs) == {"frfcfs", "para700", "trr9"}
        assert progs["frfcfs"].mitigate_reg < 0
        for nm in ("para700", "trr9"):
            assert progs[nm].mitigate_reg >= 0
            progs[nm].validate()
        # builtin program set unchanged: mitigation arms are opt-in
        assert not set(smcprog.builtin_programs()) & {"para700", "trr9"}

    def test_legacy_digests_unaffected_by_mitigate_field(self):
        """Programs without a mitigate output must hash exactly as they
        did before the field existed (compile/persistent caches)."""
        p = smcprog.frfcfs_program()
        assert p.mitigate_reg == -1
        q = dataclasses.replace(p, mitigate_reg=-1)
        assert p.digest == q.digest
        r = dataclasses.replace(p, mitigate_reg=0)
        assert r.digest != p.digest
