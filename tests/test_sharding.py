"""Sharding rules resolver + ZeRO-1 spec derivation (single-device mesh
semantics checked abstractly; full-mesh behaviour covered by the dry-run)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import model_zoo, pdefs
from repro.sharding.rules import Rules
from repro.train import optimizer as opt


class FakeMesh:
    """Axis-size-only stand-in so resolver logic is testable without
    building a 256-device mesh."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))
        self.shape = dict(sizes)


@pytest.fixture
def rules16():
    return Rules(FakeMesh({"data": 16, "model": 16}))


def test_divisible_dims_shard(rules16):
    assert rules16.resolve("heads", 32) == ("model",)
    assert rules16.resolve("vocab", 151552) == ("model",)
    assert rules16.resolve("batch", 256) == ("data",)


def test_non_divisible_fall_back(rules16):
    assert rules16.resolve("heads", 12) is None
    assert rules16.resolve("kv_heads", 2) is None
    assert rules16.resolve("vocab", 51865) is None


def test_head_dim_fallback_conditional():
    r = Rules(FakeMesh({"data": 16, "model": 16}))
    r.resolve("heads", 56)            # llava: fails
    assert r.resolve("head_dim", 128) == ("model",)
    r2 = Rules(FakeMesh({"data": 16, "model": 16}))
    r2.resolve("heads", 32)           # glm4: shards
    assert r2.resolve("head_dim", 128) is None


def test_pod_axis_dropped_single_pod(rules16):
    assert rules16.resolve("batch", 256) == ("data",)
    r3 = Rules(FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert r3.resolve("batch", 256) == ("pod", "data")


def test_param_pspecs_cover_tree(rules16):
    cfg = get_config("glm4_9b")
    model = model_zoo.build(cfg, s_max=128)
    specs = model.param_pspecs(rules16)
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree_util.tree_leaves(
        model.abstract_params()))
    # every spec is structurally valid for its param
    for d, s in zip(jax.tree_util.tree_leaves(model.defs, is_leaf=pdefs.is_def),
                    leaves):
        assert len(s) <= len(d.shape)


def test_zero1_adds_data_axis(rules16):
    cfg = get_config("glm4_9b")
    model = model_zoo.build(cfg, s_max=128)
    z = opt.zero1_pspecs(model.defs, rules16)
    flat = jax.tree_util.tree_leaves(z, is_leaf=lambda x: isinstance(x, P))
    n_data = sum("data" in str(s) for s in flat)
    assert n_data > len(flat) * 0.5  # most params gain a data shard


def test_moe_expert_sharding(rules16):
    cfg = get_config("qwen3_moe_30b_a3b")
    model = model_zoo.build(cfg, s_max=128)
    specs = model.param_pspecs(rules16)
    up = specs["blocks"]["p0"]["mlp"]["up"]  # (G, E, d, f)
    assert "model" in str(up)
