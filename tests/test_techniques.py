"""RowClone + tRCD-reduction technique behaviour (Secs. 7-8)."""
import numpy as np
import pytest

from repro.core import traces
from repro.core.dram import Geometry, RC_COPY, RC_INIT
from repro.core.profiling import DeviceModel
from repro.core.techniques import RowClone, TRCDReduction
from repro.core.timescale import JETSON_NANO, PIDRAM_LIKE


@pytest.fixture(scope="module")
def device():
    return DeviceModel(Geometry())


class TestDeviceModel:
    def test_weak_fraction_matches_paper(self, device):
        # Fig. 12: 84.5% strong / 15.5% weak
        assert abs(device.weak_fraction() - 0.155) < 0.01

    def test_trcd_all_below_nominal(self, device):
        assert device.min_trcd_ns.max() < 13.5  # all cells beat the datasheet

    def test_weak_rows_spatially_clustered(self, device):
        """Autocorrelation of weakness along rows >> iid baseline."""
        w = device.weak[0].astype(float)
        ac = np.corrcoef(w[:-1], w[1:])[0, 1]
        assert ac > 0.2

    def test_clonable_requires_same_subarray(self, device):
        assert not device.clonable(0, 10, 600)   # crosses subarray boundary
        assert not device.clonable(0, 10, 10)    # src == dst

    def test_clonable_deterministic(self, device):
        for args in ((0, 10, 11), (3, 100, 101), (7, 513, 514)):
            assert device.clonable(*args) == device.clonable(*args)


class TestRowClone:
    def test_allocator_satisfies_constraints(self, device):
        geo = Geometry()
        tr, meta = traces.copy_workload(1 << 20, geo, "rowclone", device)
        assert meta["fallback_rows"] <= meta["rows"] * 0.05
        assert (np.isin(tr.kind, (RC_COPY,)).sum()
                == meta["rows"] - meta["fallback_rows"])

    def test_speedup_over_cpu(self, device):
        rc = RowClone(JETSON_NANO, device)
        out = rc.evaluate(1 << 20, "copy", "noflush", "ts")
        assert out["rowclone"].speedup_vs_cpu > 2.0

    def test_clflush_reduces_benefit(self, device):
        rc = RowClone(JETSON_NANO, device)
        nf = rc.evaluate(1 << 18, "copy", "noflush", "ts")["rowclone"].speedup_vs_cpu
        cf = rc.evaluate(1 << 18, "copy", "clflush", "ts")["rowclone"].speedup_vs_cpu
        assert cf < nf

    def test_nots_inflates_speedup(self, device):
        """The paper's headline: platforms without time scaling report
        inflated RowClone benefits."""
        ts = RowClone(JETSON_NANO, device).evaluate(
            1 << 20, "copy", "noflush", "ts")["rowclone"].speedup_vs_cpu
        nots = RowClone(PIDRAM_LIKE, device).evaluate(
            1 << 20, "copy", "noflush", "nots")["rowclone"].speedup_vs_cpu
        assert nots > 1.5 * ts


class TestTRCD:
    def test_bloom_safety(self, device):
        t = TRCDReduction(JETSON_NANO, device)
        t.characterize()
        s = t.safety_check()
        assert s["false_negatives"] == 0          # never unsafe
        assert s["false_positive_rate"] < 0.05    # rarely pessimistic

    def test_end_to_end_speedup(self, device):
        t = TRCDReduction(JETSON_NANO, device)
        tr, _ = traces.polybench_trace(traces.POLYBENCH[3], Geometry(),
                                       max_accesses=8000)
        r = t.evaluate_trace(tr)
        assert 1.0 <= r["speedup"] < 1.25  # single-digit % (paper avg 2.75%)
