"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bloom import BloomFilter
from repro.core.emulator import BIG, Trace, run
from repro.core.timescale import JETSON_NANO
from repro.sharding.rules import Rules
from repro.launch.mesh import make_production_mesh

_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        import jax
        n = len(jax.devices())
        _MESH = jax.make_mesh((1, n), ("data", "model"))
    return _MESH


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=500))
def test_bloom_never_false_negative(keys):
    keys = np.asarray(keys, np.uint32)
    bf = BloomFilter.build(keys, m_bits=1 << 14, k=3)
    assert bf.contains(keys).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 64), st.integers(1, 8))
def test_emulator_causality_random_traces(seed, n, window):
    rng = np.random.RandomState(seed % (2 ** 31))
    tr = Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(0, 32, n),
                  dep=rng.randint(0, 2, n))
    import dataclasses
    r = run(tr, dataclasses.replace(JETSON_NANO, window=window), "ts")
    assert int(r["served"]) == n                      # everything completes
    assert (r["t_resp"][:n] < int(BIG)).all()
    assert (r["t_resp"][:n] > r["t_issue"][:n]).all()  # causality
    # issue times are monotone (in-order front end)
    assert (np.diff(r["t_issue"][:n]) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["batch", "heads", "kv_heads", "ffn", "vocab", "experts"]),
       st.integers(1, 4096))
def test_rules_divisibility_never_violated(logical, size):
    rules = Rules(_mesh())
    ax = rules.resolve(logical, size)
    n = rules._axis_size(ax)
    assert size % max(n, 1) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 48),
       st.sampled_from([1, 2, 4]), st.sampled_from(["frfcfs", "fcfs"]),
       st.sampled_from(["ts", "nots", "reference"]))
def test_fast_core_bit_identical_to_reference(seed, n, window, sched, mode):
    """The O(Q)-per-slot engine with exact slot budgets must reproduce
    the kept pre-optimization engine (`emulator.run_ref`) bit-for-bit:
    randomized traces (all request kinds incl. mid-trace NOPs and
    RowClone ops, random deps) x mode x window/scheduler, at trace
    lengths straddling the padded bucket boundaries — and batching the
    same trace through `run_many` must change nothing either."""
    import dataclasses
    from repro.core import emulator
    rng = np.random.RandomState(seed % (2 ** 31))
    tr = emulator.Trace.of(
        kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
        row=rng.randint(0, 4096, n), delta=rng.randint(0, 24, n),
        dep=rng.randint(0, 3, n))
    sysc = dataclasses.replace(JETSON_NANO, window=window, scheduler=sched)
    a = run(tr, sysc, mode)
    b = emulator.run_ref(tr, sysc, mode)
    c = emulator.run_many([tr, tr], sysc, mode)[1]
    for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
              "smc_fpga_cycles"):
        assert int(a[k]) == int(b[k]) == int(c[k]), k
    np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
    np.testing.assert_array_equal(a["t_issue"], b["t_issue"])
    np.testing.assert_array_equal(a["t_resp"], c["t_resp"])
    np.testing.assert_array_equal(a["t_issue"], c["t_issue"])
    assert a["avg_load_latency_cycles"] == b["avg_load_latency_cycles"]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([31, 32, 33, 63, 64]))
def test_fast_core_reference_with_bloom(seed, n):
    """Same bit-identity contract on the Bloom-filter (reduced-tRCD)
    arm, pinned to bucket-boundary lengths."""
    from repro.core import emulator
    rng = np.random.RandomState(seed % (2 ** 31))
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 100).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    bloom = (bf.bits, bf.k, bf.m_bits)
    tr = emulator.Trace.of(
        kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
        row=rng.randint(0, 4096, n), delta=rng.randint(1, 8, n),
        dep=rng.randint(0, 2, n))
    a = run(tr, JETSON_NANO, "ts", bloom=bloom)
    b = emulator.run_ref(tr, JETSON_NANO, "ts", bloom=bloom)
    assert int(a["exec_cycles"]) == int(b["exec_cycles"])
    np.testing.assert_array_equal(a["t_resp"], b["t_resp"])


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 48),
       st.sampled_from([1, 2, 4]), st.sampled_from(["frfcfs", "fcfs"]),
       st.sampled_from(["ts", "nots", "reference"]))
def test_policy_program_bit_identical_to_legacy(seed, n, window, sched, mode):
    """The built-in FR-FCFS/FCFS policy programs (the MC-policy VM
    inside the scan) must reproduce the legacy `sys.scheduler` string
    path bit-for-bit — and `run` == `run_many` == `run_ref` must keep
    holding with a policy attached — across randomized traces (all
    request kinds incl. mid-trace NOPs, random deps), windows, and
    modes."""
    import dataclasses
    from repro.core import emulator, smcprog
    rng = np.random.RandomState(seed % (2 ** 31))
    tr = Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(0, 24, n),
                  dep=rng.randint(0, 3, n))
    prog = (smcprog.frfcfs_program() if sched == "frfcfs"
            else smcprog.fcfs_program())
    sys_leg = dataclasses.replace(JETSON_NANO, window=window, scheduler=sched)
    sys_prog = dataclasses.replace(sys_leg, policy=prog)
    a = run(tr, sys_leg, mode)
    b = run(tr, sys_prog, mode)
    c = emulator.run_many([tr, tr], sys_prog, mode)[1]
    d = emulator.run_ref(tr, sys_prog, mode)
    for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
              "smc_fpga_cycles"):
        assert int(a[k]) == int(b[k]) == int(c[k]) == int(d[k]), k
    np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
    np.testing.assert_array_equal(a["t_issue"], b["t_issue"])
    np.testing.assert_array_equal(a["t_resp"], c["t_resp"])
    np.testing.assert_array_equal(a["t_resp"], d["t_resp"])


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans())
def test_overlapped_executor_bit_identical_to_serial(seed, force_shard):
    """PR 5 contract: `Campaign.run()` (groups overlapped across the
    executor's worker pool, batch axis shard_mapped when forced/multi-
    device) must be bit-identical to `run(serial=True)` (the PR 4
    in-order group loop) across a randomized mixed grid of modes x
    policies x bloom arms x length buckets, with records in add order."""
    import dataclasses
    from repro.core import emulator, smcprog
    from repro.core.campaign import Campaign
    rng = np.random.RandomState(seed % (2 ** 31))
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 100).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    bloom = (bf.bits, bf.k, bf.m_bits)
    prog = smcprog.frfcfs_program()
    c = Campaign()
    for i in range(int(rng.randint(2, 5))):
        n = int(rng.randint(8, 90))
        tr = Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(0, 24, n), dep=rng.randint(0, 3, n))
        mode = ("ts", "nots", "reference")[int(rng.randint(3))]
        c.add(tr, JETSON_NANO, mode=mode, i=i, arm="plain")
        if rng.rand() < 0.5:
            c.add(tr, JETSON_NANO, mode="ts", bloom=bloom, i=i, arm="bloom")
        if rng.rand() < 0.5:
            c.add(tr, dataclasses.replace(JETSON_NANO, policy=prog),
                  mode=mode, i=i, arm="policy")
    old = emulator.set_sharding("force" if force_shard else "auto")
    try:
        b = c.run()
    finally:
        emulator.set_sharding(old)
    a = c.run(serial=True)
    assert [(r["i"], r["arm"]) for r in a] == [(r["i"], r["arm"]) for r in b]
    for x, y in zip(a, b):
        assert int(x["exec_cycles"]) == int(y["exec_cycles"])
        assert int(x["row_hits"]) == int(y["row_hits"])
        np.testing.assert_array_equal(x["t_resp"], y["t_resp"])
        np.testing.assert_array_equal(x["t_issue"], y["t_issue"])


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3))
def test_sweep_service_bit_identical_to_serial(seed, n_clients):
    """ISSUE 9 contract: K concurrent `SweepClient`s submitting an
    interleaved randomized grid through one `SweepServer` (points from
    different clients coalescing into shared dispatches) get records
    bit-identical to `Campaign.run(serial=True)` over the same points,
    each client's results in its own submission order."""
    import dataclasses
    import threading
    from repro.core import smcprog
    from repro.core.campaign import Campaign, Point
    from repro.service import SweepClient, SweepServer
    rng = np.random.RandomState(seed % (2 ** 31))
    bf = BloomFilter.build(rng.randint(0, 1 << 19, 100).astype(np.uint32),
                           m_bits=1 << 14, k=3)
    bloom = (bf.bits, bf.k, bf.m_bits)
    sys_pol = dataclasses.replace(JETSON_NANO, policy=smcprog.frfcfs_program())
    pts = []
    for i in range(int(rng.randint(3, 7))):
        n = int(rng.randint(8, 90))
        tr = Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                      row=rng.randint(0, 4096, n),
                      delta=rng.randint(0, 24, n), dep=rng.randint(0, 3, n))
        mode = ("ts", "nots", "reference")[int(rng.randint(3))]
        sysc = (JETSON_NANO, sys_pol)[int(rng.randint(2))]
        bl = bloom if mode == "ts" and rng.rand() < 0.5 else None
        pts.append(Point(tr, sysc, mode, bl, {"idx": i}))
    c = Campaign()
    for p in pts:
        c.add(p.trace, p.sys, mode=p.mode, bloom=p.bloom, **p.meta)
    ref = {r["idx"]: r for r in c.run(serial=True)}
    got, errs = {}, []
    with SweepServer(coalesce_window_s=0.05) as srv:
        def drive(k):
            try:
                cli = SweepClient(server=srv, name=f"c{k}")
                mine = [p for j, p in enumerate(pts) if j % n_clients == k]
                cli.submit_points(mine)
                for p, r in zip(mine, cli.collect()):
                    assert r["idx"] == p.meta["idx"]
                    got[r["idx"]] = r
            except BaseException as e:
                errs.append(e)
        threads = [threading.Thread(target=drive, args=(k,))
                   for k in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180)
    assert not errs, errs
    assert set(got) == set(ref)
    for i, r in ref.items():
        assert int(got[i]["exec_cycles"]) == int(r["exec_cycles"])
        np.testing.assert_array_equal(got[i]["t_resp"], r["t_resp"])
        np.testing.assert_array_equal(got[i]["t_issue"], r["t_issue"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 200),
       st.integers(8, 64), st.sampled_from([1, 2, 4]),
       st.sampled_from(["ts", "nots", "reference"]), st.integers(0, 60))
def test_stream_bit_identical_to_single_shot(seed, n, chunk, window, mode,
                                             nop_run):
    """ISSUE 7 anchor: the constant-memory chunked-window driver
    (`run_stream`) must equal the single-shot `run` bit-for-bit on any
    trace both support — across random chunk sizes (so chunk boundaries
    land anywhere, including inside dependency windows and mid-trace
    NOP runs), windows, and modes. The frozen-slot handoff makes the
    streamed slot sequence the single-shot sequence with identity steps
    inserted; this property is the empirical pin of that argument."""
    import dataclasses
    from repro.core import emulator
    rng = np.random.RandomState(seed % (2 ** 31))
    kind = rng.randint(0, 5, n)
    if nop_run and n > nop_run:  # idle gap crossing chunk boundaries
        at = int(rng.randint(0, n - nop_run))
        kind[at:at + nop_run] = 4
    tr = Trace.of(kind=kind, bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(0, 24, n),
                  dep=rng.randint(0, 3, n))
    sysc = dataclasses.replace(JETSON_NANO, window=window)
    a = run(tr, sysc, mode)
    s = emulator.run_stream(tr, sysc, mode, chunk=chunk, dep_max=3)
    for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
              "smc_fpga_cycles"):
        assert int(a[k]) == int(s[k]), k
    assert a["avg_load_latency_cycles"] == s["avg_load_latency_cycles"]
    np.testing.assert_array_equal(a["t_resp"][:n], s["t_resp"])
    np.testing.assert_array_equal(a["t_issue"][:n], s["t_issue"])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(4, 70),
       st.sampled_from(["ts", "nots"]), st.booleans(), st.booleans())
def test_policy_axis_bit_identical_to_staged(seed, n, mode, faults,
                                             streaming):
    """ISSUE 10 anchor: staged-constant VM == runtime-operand VM ==
    vmapped policy axis, bit for bit — over random PolicyBuilder
    programs (mixed table-length buckets included), ts/nots, faults
    on/off, and the streaming chunked-window driver. The policy table
    is DATA on the runtime path; this property is what licenses sweeping
    hundreds of policies through one executable."""
    import dataclasses
    from repro.core import emulator, smcprog
    from repro.core.faults import FaultModel
    from repro.core.policysearch import random_program
    rng = np.random.RandomState(seed % (2 ** 31))
    tr = Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(0, 24, n),
                  dep=rng.randint(0, 3, n))
    progs = [random_program(rng, name=f"p{i}") for i in range(3)]
    if rng.rand() < 0.5:  # force a second (16-row) table bucket
        b = smcprog.PolicyBuilder()
        v = b.score_age()
        for _ in range(5):
            v = b.add(v, b.mul(v, v))
        progs.append(b.build(score=v, name="wide"))
    sysc = JETSON_NANO
    if faults:
        sysc = sysc.with_faults(FaultModel(
            seed=int(seed % 97), hammer_threshold=64,
            hammer_flip_fp=30000, weak_fp=200))
        progs += list(smcprog.mitigation_programs().values())
    axis = emulator.run_policies(tr, sysc, progs, mode=mode,
                                 derive_cost=False, serial=True)
    costs = [int(sysc.smc_cycles_per_decision)] * len(progs)
    for p, r in zip(progs, axis):
        staged = run(tr, dataclasses.replace(sysc, policy=p), mode)
        for k in ("exec_cycles", "row_hits", "served", "dram_ticks",
                  "smc_fpga_cycles"):
            assert int(staged[k]) == int(r[k]), (p.name, k)
        np.testing.assert_array_equal(staged["t_resp"][:n],
                                      r["t_resp"][:n])
        np.testing.assert_array_equal(staged["t_issue"][:n],
                                      r["t_issue"][:n])
    if streaming:
        chunk = int(rng.randint(8, 64))
        stream = emulator.run_stream_many(
            [tr] * len(progs), sysc, mode, chunk=chunk, dep_max=3,
            policies=progs, policy_costs=costs, serial=True)
        for p, a, s in zip(progs, axis, stream):
            assert int(a["exec_cycles"]) == int(s["exec_cycles"]), p.name
            np.testing.assert_array_equal(a["t_resp"][:n], s["t_resp"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_emulator_deterministic(seed):
    rng = np.random.RandomState(seed)
    n = 64
    tr = Trace.of(kind=np.zeros(n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=np.full(n, 3))
    a = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
    b = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
    assert a == b
