"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bloom import BloomFilter
from repro.core.emulator import BIG, Trace, run
from repro.core.timescale import JETSON_NANO
from repro.sharding.rules import Rules
from repro.launch.mesh import make_production_mesh

_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        import jax
        n = len(jax.devices())
        _MESH = jax.make_mesh((1, n), ("data", "model"))
    return _MESH


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=500))
def test_bloom_never_false_negative(keys):
    keys = np.asarray(keys, np.uint32)
    bf = BloomFilter.build(keys, m_bits=1 << 14, k=3)
    assert bf.contains(keys).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 64), st.integers(1, 8))
def test_emulator_causality_random_traces(seed, n, window):
    rng = np.random.RandomState(seed % (2 ** 31))
    tr = Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(0, 32, n),
                  dep=rng.randint(0, 2, n))
    import dataclasses
    r = run(tr, dataclasses.replace(JETSON_NANO, window=window), "ts")
    assert int(r["served"]) == n                      # everything completes
    assert (r["t_resp"][:n] < int(BIG)).all()
    assert (r["t_resp"][:n] > r["t_issue"][:n]).all()  # causality
    # issue times are monotone (in-order front end)
    assert (np.diff(r["t_issue"][:n]) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["batch", "heads", "kv_heads", "ffn", "vocab", "experts"]),
       st.integers(1, 4096))
def test_rules_divisibility_never_violated(logical, size):
    rules = Rules(_mesh())
    ax = rules.resolve(logical, size)
    n = rules._axis_size(ax)
    assert size % max(n, 1) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_emulator_deterministic(seed):
    rng = np.random.RandomState(seed)
    n = 64
    tr = Trace.of(kind=np.zeros(n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=np.full(n, 3))
    a = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
    b = int(run(tr, JETSON_NANO, "ts")["exec_cycles"])
    assert a == b
