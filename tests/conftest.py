import warnings

import pytest

warnings.filterwarnings("ignore", category=RuntimeWarning)


def pytest_configure(config):
    # donation is best-effort by design in the emulator (see
    # emulator._build_runner); pytest's warning capture overrides the
    # module-level filter installed there, so re-add it here
    config.addinivalue_line(
        "filterwarnings",
        "ignore:Some donated buffers were not usable")


def tiny_cfg(name, **over):
    from repro.configs import get_config
    cfg = get_config(name)
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=512, head_dim=16)
    base.update(over)
    return cfg.scaled(**base)


@pytest.fixture
def rng():
    import jax
    return jax.random.PRNGKey(0)
