import warnings

import pytest

warnings.filterwarnings("ignore", category=RuntimeWarning)


def tiny_cfg(name, **over):
    from repro.configs import get_config
    cfg = get_config(name)
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=512, head_dim=16)
    base.update(over)
    return cfg.scaled(**base)


@pytest.fixture
def rng():
    import jax
    return jax.random.PRNGKey(0)
