"""ISSUE 10 pins: the runtime policy operand and the vmapped policy
axis must be bit-identical to the staged-constant VM — across engines
(fast/ref), modes (ts/nots), faults on/off, streaming windows, and
mixed table-length buckets — while compiling once per BUCKET, never per
program. Deterministic versions of the hypothesis property in
tests/test_property.py (hypothesis is optional in this container)."""
import dataclasses

import numpy as np
import pytest

from repro.core import emulator, smcprog
from repro.core.campaign import Campaign, Point
from repro.core.emulator import Trace, run, run_many, run_policies
from repro.core.faults import FaultModel
from repro.core.policysearch import (crossover, mutate, random_program,
                                     search)
from repro.core.timescale import JETSON_NANO

ALL_FIELDS = ("exec_cycles", "row_hits", "served", "dram_ticks",
              "smc_fpga_cycles")


def mk_trace(seed=0, n=60):
    rng = np.random.RandomState(seed)
    return Trace.of(kind=rng.randint(0, 5, n), bank=rng.randint(0, 16, n),
                    row=rng.randint(0, 4096, n), delta=rng.randint(0, 24, n),
                    dep=rng.randint(0, 3, n))


def program_pool(seed=11, n_random=4):
    rng = np.random.RandomState(seed)
    progs = list(smcprog.builtin_programs().values())
    progs += [random_program(rng, name=f"r{i}") for i in range(n_random)]
    return progs


def assert_same(a, b, n, label=""):
    for k in ALL_FIELDS:
        assert int(a[k]) == int(b[k]), (label, k)
    np.testing.assert_array_equal(a["t_resp"][:n], b["t_resp"][:n])
    np.testing.assert_array_equal(a["t_issue"][:n], b["t_issue"][:n])
    assert a["avg_load_latency_cycles"] == b["avg_load_latency_cycles"], label


class TestRuntimeOperandBitIdentity:
    @pytest.mark.parametrize("mode", ["ts", "nots"])
    def test_axis_matches_staged(self, mode):
        """One dispatch over the policy axis == per-program staged
        constants, every output field."""
        tr = mk_trace(0)
        progs = program_pool()
        axis = run_policies(tr, JETSON_NANO, progs, mode=mode,
                            derive_cost=False, serial=True)
        for p, r in zip(progs, axis):
            staged = run(tr, dataclasses.replace(JETSON_NANO, policy=p),
                         mode)
            assert_same(staged, r, tr.n, p.name)

    def test_derive_cost_matches_with_policy(self):
        """derive_cost=True charges each program its length-derived SMC
        cost — the with_policy semantics — visibly in nots mode."""
        tr = mk_trace(1)
        progs = program_pool(n_random=2)
        axis = run_policies(tr, JETSON_NANO, progs, mode="nots",
                            derive_cost=True, serial=True)
        for p, r in zip(progs, axis):
            staged = run(tr, JETSON_NANO.with_policy(p), "nots")
            assert_same(staged, r, tr.n, p.name)

    def test_ref_engine_matches_fast(self):
        """The kept pre-optimization engine mirrors the table VM."""
        tr = mk_trace(2)
        progs = program_pool(n_random=2)
        costs = [p.smc_cycles() for p in progs]
        fast = run_many([tr] * len(progs), JETSON_NANO, "ts",
                        policies=progs, policy_costs=costs, serial=True)
        ref = emulator.run_ref_many([tr] * len(progs), JETSON_NANO, "ts",
                                    policies=progs, policy_costs=costs,
                                    serial=True)
        for p, f, r in zip(progs, fast, ref):
            assert_same(f, r, tr.n, p.name)

    def test_streaming_matches_single_shot(self):
        """The chunked-window driver carries the policy operand through
        every window; chunk boundaries change nothing. Stream results
        are exact-length; single-shot are bucket-padded."""
        tr = mk_trace(3, n=150)
        progs = program_pool(n_random=2)
        costs = [p.smc_cycles() for p in progs]
        single = run_many([tr] * len(progs), JETSON_NANO, "ts",
                          policies=progs, policy_costs=costs, serial=True)
        stream = emulator.run_stream_many(
            [tr] * len(progs), JETSON_NANO, "ts", chunk=64,
            policies=progs, policy_costs=costs, serial=True)
        for p, a, s in zip(progs, single, stream):
            for k in ALL_FIELDS:
                assert int(a[k]) == int(s[k]), (p.name, k)
            np.testing.assert_array_equal(a["t_resp"][:tr.n], s["t_resp"])
            np.testing.assert_array_equal(a["t_issue"][:tr.n], s["t_issue"])

    def test_faults_and_mitigation_on_the_axis(self):
        """Mitigation programs (PARA/TRR) ride the axis under the fault
        model: BER/flips/mitigations match the staged path exactly."""
        fm = FaultModel(seed=3, hammer_threshold=64, hammer_flip_fp=30000,
                        weak_fp=200)
        sysf = JETSON_NANO.with_faults(fm)
        tr = mk_trace(4, n=100)
        progs = list(smcprog.mitigation_programs().values())
        axis = run_policies(tr, sysf, progs, mode="ts",
                            derive_cost=False, serial=True)
        for p, r in zip(progs, axis):
            staged = run(tr, dataclasses.replace(sysf, policy=p), "ts")
            assert_same(staged, r, tr.n, p.name)
            for k in ("flips", "mitigations", "weak_hits"):
                if k in staged:
                    assert int(staged[k]) == int(r[k]), (p.name, k)


class TestCompileScaling:
    def test_one_compile_per_bucket(self):
        """The axis contract: compiles count table-length BUCKETS, not
        programs. 8 bucket-8 programs + 1 bucket-32 program == exactly
        2 executables."""
        tr = mk_trace(5, n=40)
        progs = program_pool(n_random=2)          # all bucket 8
        b = smcprog.PolicyBuilder()
        v = b.score_age()
        for _ in range(10):                       # 21 ops -> bucket 32
            v = b.add(v, b.const(1))
        progs.append(b.build(score=v, name="long21"))
        assert {smcprog.table_bucket(p.n_ops) for p in progs} == {8, 32}
        emulator.cache_clear()
        run_policies(tr, JETSON_NANO, progs, mode="ts", serial=True)
        assert emulator.cache_stats()["misses"] == 2

    def test_repeat_sweep_compiles_nothing(self):
        tr = mk_trace(6, n=40)
        rng = np.random.RandomState(0)
        progs = [random_program(rng, name=f"p{i}") for i in range(12)]
        run_policies(tr, JETSON_NANO, progs, mode="ts", serial=True)
        before = emulator.cache_stats()["misses"]
        rng2 = np.random.RandomState(99)          # different CONTENT
        progs2 = [random_program(rng2, name=f"q{i}") for i in range(12)]
        run_policies(tr, JETSON_NANO, progs2, mode="ts", serial=True)
        assert emulator.cache_stats()["misses"] == before


class TestCampaignPolicyAxis:
    def test_axis_default_one_group_matches_legacy(self):
        tr = mk_trace(7)
        progs = program_pool(n_random=2)
        c = Campaign()
        c.add_policy_grid(tr, JETSON_NANO, progs)
        assert c.n_groups() == 1                  # one bucket, one group
        axis = c.run(serial=True)
        c2 = Campaign()
        c2.add_policy_grid(tr, JETSON_NANO, progs, policy_axis=False)
        assert c2.n_groups() == len(progs)
        legacy = c2.run(serial=True)
        for a, b in zip(axis, legacy):
            assert a["policy"] == b["policy"]
            assert_same(a, b, tr.n, a["policy"])

    def test_mixed_buckets_raise_naming_program(self):
        b = smcprog.PolicyBuilder()
        v = b.score_age()
        for _ in range(5):
            v = b.add(v, b.mul(v, v))             # 11 ops -> bucket 16
        big = b.build(score=v, name="wide-prog")
        with pytest.raises(ValueError, match="wide-prog"):
            Campaign().add_policy_grid(
                mk_trace(8, n=16), JETSON_NANO,
                [smcprog.frfcfs_program(), big])

    def test_checkpoint_digest_separates_policies(self):
        """Two points differing only in their runtime policy must get
        different content digests (checkpoint addresses)."""
        tr = mk_trace(9, n=16)
        a = Point(tr, JETSON_NANO, "ts", None, {},
                  policy=smcprog.frfcfs_program(), policy_cost=400)
        b = Point(tr, JETSON_NANO, "ts", None, {},
                  policy=smcprog.fcfs_program(), policy_cost=400)
        plain = Point(tr, JETSON_NANO, "ts", None, {})
        assert len({a.content_digest(), b.content_digest(),
                    plain.content_digest()}) == 3

    def test_service_policy_axis_stats(self):
        from repro.service.server import SweepServer
        tr = mk_trace(10, n=24)
        progs = program_pool(n_random=0)
        with SweepServer(max_batch=64, coalesce_window_s=0.02) as srv:
            cl = srv.register("c1")
            pts = [Point(tr, JETSON_NANO, "ts", None, {"policy": p.name},
                         policy=p, policy_cost=p.smc_cycles())
                   for p in progs]
            futs = srv.submit_points(cl, pts)
            recs = [f.result(300) for f in futs]
            st = srv.stats()
        assert st["policies_per_dispatch"] == float(len(progs))
        assert st["dispatches"]["policy_points"] == len(progs)
        assert sum(g["policies"] for g in st["groups"].values()) \
            == len(progs)
        legacy = run_policies(tr, JETSON_NANO, progs, mode="ts",
                              serial=True)
        for r, l in zip(recs, legacy):
            np.testing.assert_array_equal(r["t_resp"], l["t_resp"])


class TestPackingAndVM:
    def test_pack_program_layout(self):
        p = smcprog.frfcfs_program()
        t = smcprog.pack_program(p)
        assert t.shape == (9, 4) and t.dtype == np.int32
        assert tuple(t[0]) == (p.n_ops, p.score_reg, p.boost_reg,
                               p.mitigate_reg)
        assert (t[1 + p.n_ops:] == 0).all()       # OP_CONST 0 padding

    def test_pack_too_small_bucket_names_program(self):
        rng = np.random.RandomState(0)
        p = dataclasses.replace(random_program(rng, max_ops=8),
                                name="fat")
        with pytest.raises(ValueError, match="fat"):
            smcprog.pack_program(p, bucket=1)

    def test_table_bucket_floor_and_growth(self):
        assert smcprog.table_bucket(1) == 8
        assert smcprog.table_bucket(8) == 8
        assert smcprog.table_bucket(9) == 16
        assert smcprog.table_bucket(17) == 32
        with pytest.raises(ValueError):
            smcprog.table_bucket(0)

    def test_validate_errors_carry_row_and_opname(self):
        bad = smcprog.PolicyProgram(
            ((smcprog.OP_AGE, 0, 0, 0), (smcprog.OP_ADD, 0, 1, 0)),
            score_reg=1)
        with pytest.raises(ValueError, match=r"row 1 \(op_add\)"):
            bad.validate()

    def test_pallas_kernel_matches_reference(self):
        from repro.kernels.policy_vm import policy_vm_scores
        from repro.kernels.ref import policy_vm_ref
        rng = np.random.RandomState(3)
        progs = program_pool(n_random=6) \
            + list(smcprog.mitigation_programs().values())
        tables = smcprog.pack_stack(progs, bucket=8)
        envm = rng.randint(-5, 1 << 16,
                           (smcprog.N_LOADS, 32)).astype(np.int32)
        ref = np.asarray(policy_vm_ref(tables, envm))
        ker = np.asarray(policy_vm_scores(tables, envm, interpret=True))
        np.testing.assert_array_equal(ref, ker)


class TestPolicySearch:
    def test_generators_always_valid(self):
        rng = np.random.RandomState(0)
        for _ in range(200):
            p = random_program(rng)
            m = mutate(p, rng)
            c = crossover(p, random_program(rng), rng)
            for q in (p, m, c):
                q.validate()
                assert q.n_ops <= 8

    def test_search_is_deterministic_and_never_below_baseline(self):
        tr = mk_trace(12, n=48)
        a = search(tr, JETSON_NANO, generations=2, population=6,
                   seed=5, serial=True)
        b = search(tr, JETSON_NANO, generations=2, population=6,
                   seed=5, serial=True)
        assert a.best.digest == b.best.digest
        assert a.best_fitness == b.best_fitness
        # baseline is in the seed population: the result can only tie
        # or beat it
        assert a.best_fitness <= a.baseline_fitness
        assert a.n_dispatches <= 2
