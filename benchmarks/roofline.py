"""Roofline table from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json and derives, per (arch x shape x mesh):
compute / memory / collective terms (seconds), the dominant bottleneck,
MODEL_FLOPS = 6*N*D (train) or 2*N_active*tokens (serve), and the
useful-compute ratio. Markdown + CSV emitters feed EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config, get_shape

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# XLA cost_analysis counts one FLOP per MAC in dots; calibration factor
# measured by benchmarks.run --calibrate (see EXPERIMENTS.md §Roofline).
XLA_FLOP_PER_MAC = 2.0


def active_params(cfg):
    from repro.models import model_zoo
    n = model_zoo.build(cfg, s_max=128).n_params()
    if cfg.moe is None:
        return n, n
    m = cfg.moe
    # expert params scale by top_k / n_experts when active
    expert = (cfg.n_layers // m.every) * m.n_experts * (
        (3 if cfg.act in ("swiglu", "geglu") else 2) * cfg.d_model * m.d_ff)
    active = n - expert + expert * m.top_k / m.n_experts
    return n, int(active)


def model_flops(cfg, shape):
    n, n_active = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6 * n_active * tokens
    if shape.kind == "prefill":
        return 2 * n_active * tokens
    return 2 * n_active * shape.global_batch  # decode: one token per request


def load_records(mesh="sp", tag=""):
    recs = []
    suffix = f"--{mesh}{'-' + tag if tag else ''}.json"
    for f in sorted(glob.glob(os.path.join(ART, f"*{suffix}"))):
        r = json.load(open(f))
        if "error" in r:
            recs.append(r)
            continue
        cfg = get_config(r["arch"])
        shape = get_shape(r["shape"])
        mf = model_flops(cfg, shape)
        hlo_global = r["per_device"]["flops"] * r["chips"] * 2 / XLA_FLOP_PER_MAC
        r["model_flops"] = mf
        r["useful_ratio"] = mf / max(hlo_global, 1)
        recs.append(r)
    return recs


def markdown_table(recs):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS | useful | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: {r['error'][:40]} "
                         "| | | | | | |")
            continue
        t = r["roofline_s"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | {r['dominant']} | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['memory_analysis']['temp_size_in_bytes'] / 2**30:.1f} |")
    return "\n".join(lines)


def csv_rows(recs):
    rows = []
    for r in recs:
        if "error" in r:
            continue
        t = r["roofline_s"]
        dom = r["dominant"]
        rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                     round(t[dom], 5), f"dominant={dom}"))
    return rows


if __name__ == "__main__":
    recs = load_records("sp")
    print(markdown_table(recs))
