"""Benchmark orchestrator: one section per paper table/figure.

Prints ``name,value,derived`` CSV rows. ``--quick`` trims trace sizes
for smoke use and exits non-zero if any section fails, so it doubles as
a CI smoke gate (``python -m benchmarks.run --quick``); ``--section
<name>`` runs one section (e.g. ``campaign_speed`` for the batched-vs-
looped sweep comparison).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks import kernels_bench, paper, roofline

    sections = {
        "timescale": paper.bench_timescale_validation,          # Sec. 6
        "latency_profile": paper.bench_latency_profile,         # Fig. 8
        "rowclone_noflush": lambda: paper.bench_rowclone("noflush"),   # Fig. 10
        "rowclone_clflush": lambda: paper.bench_rowclone("clflush"),   # Fig. 11
        "trcd_profile": paper.bench_trcd_profile,               # Fig. 12
        "trcd_endtoend": (lambda: paper.bench_trcd_endtoend(8)) if args.quick
        else paper.bench_trcd_endtoend,                          # Fig. 13
        "sim_speed": paper.bench_sim_speed,                     # Fig. 14
        "campaign_speed": (lambda: paper.bench_campaign_speed(3))
        if args.quick else paper.bench_campaign_speed,          # run_many
        "lm_traces": paper.bench_lm_traces,                     # framework tie-in
        "kernels": kernels_bench.bench_kernels,
        "roofline": lambda: roofline.csv_rows(roofline.load_records("sp")),
    }
    if args.section:
        if args.section not in sections:
            ap.error(f"unknown section {args.section!r}; "
                     f"choose from: {', '.join(sections)}")
        sections = {args.section: sections[args.section]}

    print("name,value,derived")
    failures = 0
    for name, fn in sections.items():
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}:{e}")
        print(f"_section_{name}_seconds,{time.perf_counter()-t0:.1f},wall",
              flush=True)
    print(f"_failures,{failures},smoke_gate")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
