"""Benchmark orchestrator: one section per paper table/figure.

Prints ``name,value,derived`` CSV rows. ``--quick`` trims trace sizes
for smoke use and exits non-zero if any section fails OR a perf gate
row is missing/out of range: the engine's steady-state speedup
(``sim_speed_steady_speedup_x``, >=2x warm-cache at N=4000 vs the
pre-optimization core) and the MC-policy-VM interpreter overhead
(``policy_sweep_interp_overhead_x``, <=1.3x vs the hard-coded
scheduler) — so it doubles as a CI smoke gate that catches throughput
regressions (``python -m benchmarks.run --quick``). ``--section <name>``
runs one section (e.g. ``sim_speed`` for the engine throughput gate,
``campaign_speed`` for the batched-vs-looped sweep comparison,
``policy_sweep`` for the policy-VM overhead gate and built-in grid).
``--out <path>`` additionally writes a machine-readable BENCH_<n>.json
(env fingerprint header + section rows + wall times + compile-cache
stats) so the perf trajectory is tracked and comparable across PRs and
environments; ``--quick`` defaults it to ``artifacts/BENCH_quick.json``.
PR 5 gates (``--quick``): the overlapped campaign executor must beat
the serial group loop >= 1.5x warm (``executor_speed_overlap_speedup_x``,
multicore hosts), and a second process over the persistent XLA cache
must skip every recompile (``executor_speed_pcache_second_hits`` > 0,
``..._misses`` == 0).
PR 7 gates (``--quick``, section ``streaming``): a 1M-request stream
through the constant-memory chunked-window driver must finish with
per-chunk throughput >= 0.9x the 8x4000 single-shot steady state
(``streaming_tput_ratio``), exactly ONE streaming compile key
(``streaming_compile_keys`` — length-independent by construction), and
peak RSS within ``STREAM_RSS_BUDGET_MB`` (``streaming_rss_mb``; the
budget is recorded in the BENCH json for trajectory comparison — a
length-dependent padded scan at this size would be gigabytes). The RSS
bound is enforced only in ``--section streaming`` runs: peak RSS is
process-wide, so other sections' allocations own it in a full run and
the row is informational there.
PR 8 gates (``--quick``, section ``faults``): ``faults=None`` must
leave compile/group keys untouched (``faults_off_compile_keys_equal``
== 1), the cheapest attached fault carry must cost <= 1.05x the
no-fault-model arm (``faults_off_overhead_x``), and a checkpointed
campaign re-run must recompute zero finished groups
(``faults_ckpt_resume_recomputed`` == 0).
ISSUE 9 gates (``--quick``, section ``service``): K=4 concurrent
clients sharing one ``SweepServer`` must keep >= 0.7*K the aggregate
throughput of a solo client on its own server
(``service_scaling_x`` — only reachable through cross-client
coalescing on a single device), dispatches must actually mix clients
(``service_clients_per_dispatch`` > 1), and zero points may be
rejected at the default admission bounds (``service_rejected`` == 0).
The reference run is ``--section service --out artifacts/BENCH_9.json``.
ISSUE 10 gates (``--quick``, section ``policy_axis``): a 256-candidate
policy sweep through the runtime-operand axis must compile once per
table-length BUCKET, not per program (``policy_axis_compiles`` ==
``policy_axis_buckets``), beat the PR-4 staged per-program loop >= 5x
per policy (``policy_axis_speedup_x``), stay bit-identical to the
staged path (``policy_axis_bitident`` == 1), and the Pallas policy-VM
kernel must match its jnp reference (``policy_axis_pallas_bitident``
== 1). The reference run is ``--section policy_axis --out
artifacts/BENCH_10.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

STEADY_ROW = "sim_speed_steady_speedup_x"
STEADY_GATE = 2.0
POLICY_ROW = "policy_sweep_interp_overhead_x"
POLICY_GATE = 1.3  # policy-VM scan must stay within 1.3x of hard-coded
EXEC_ROW = "executor_speed_overlap_speedup_x"
EXEC_GATE = 1.5    # overlapped executor vs serial group loop, warm cache
PCACHE_HITS_ROW = "executor_speed_pcache_second_hits"
PCACHE_MISSES_ROW = "executor_speed_pcache_second_misses"
STREAM_RATIO_ROW = "streaming_tput_ratio"
STREAM_RATIO_GATE = 0.9   # stream vs 8x4000 single-shot steady throughput
STREAM_KEYS_ROW = "streaming_compile_keys"
STREAM_RSS_ROW = "streaming_rss_mb"
STREAM_RSS_BUDGET_MB = 2048  # whole-process peak; O(chunk) driver state
FAULTS_KEYS_ROW = "faults_off_compile_keys_equal"
FAULTS_OFF_ROW = "faults_off_overhead_x"
FAULTS_OFF_GATE = 1.05  # disabled fault carry vs no fault model at all
FAULTS_CKPT_ROW = "faults_ckpt_resume_recomputed"
SERVICE_K = 4              # clients in the shared-server arm
SERVICE_SCALING_ROW = "service_scaling_x"
SERVICE_SCALING_GATE = 0.7 * SERVICE_K  # K tenants sharing one engine
#                          must keep >= 0.7*K of a solo tenant's rate
#                          (cross-client coalescing + batch amortization)
SERVICE_COAL_ROW = "service_clients_per_dispatch"
SERVICE_REJ_ROW = "service_rejected"
PAXIS_COMPILES_ROW = "policy_axis_compiles"
PAXIS_BUCKETS_ROW = "policy_axis_buckets"
PAXIS_SPEEDUP_ROW = "policy_axis_speedup_x"
PAXIS_SPEEDUP_GATE = 5.0  # batched axis vs staged per-program loop
PAXIS_BITIDENT_ROW = "policy_axis_bitident"
PAXIS_PALLAS_ROW = "policy_axis_pallas_bitident"


def _env_header() -> dict:
    """Environment fingerprint for BENCH_<n>.json comparability: the
    same rows mean different things on a different jax/jaxlib, device
    topology, or scan runtime (see ROADMAP perf note)."""
    import jax
    import jaxlib
    devs = jax.local_devices()
    flags = os.environ.get("XLA_FLAGS", "")
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "device_count": len(devs),
        "device_kind": devs[0].device_kind if devs else "none",
        "platform": devs[0].platform if devs else "none",
        "cpu_count": os.cpu_count(),
        "fast_cpu_scan": "xla_cpu_use_thunk_runtime=false" in flags,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="write section rows + wall times + cache stats "
                         "as JSON (BENCH_<n>.json)")
    args = ap.parse_args()

    # must precede the first jax computation: the XLA:CPU thunk runtime
    # is a 30-40x steady-state slowdown on the emulator scan
    from repro.utils.jax_compat import enable_fast_cpu_scan
    enable_fast_cpu_scan()

    from benchmarks import kernels_bench, paper, roofline
    from repro.core import emulator

    sections = {
        "timescale": paper.bench_timescale_validation,          # Sec. 6
        "latency_profile": paper.bench_latency_profile,         # Fig. 8
        "rowclone_noflush": lambda: paper.bench_rowclone("noflush"),   # Fig. 10
        "rowclone_clflush": lambda: paper.bench_rowclone("clflush"),   # Fig. 11
        "trcd_profile": paper.bench_trcd_profile,               # Fig. 12
        "trcd_endtoend": (lambda: paper.bench_trcd_endtoend(8)) if args.quick
        else paper.bench_trcd_endtoend,                          # Fig. 13
        "sim_speed": paper.bench_sim_speed,                     # Fig. 14
        "campaign_speed": (lambda: paper.bench_campaign_speed(3))
        if args.quick else paper.bench_campaign_speed,          # run_many
        "policy_sweep": (lambda: paper.bench_policy_sweep(4, 400))
        if args.quick else paper.bench_policy_sweep,            # MC-policy VM
        "executor_speed": (lambda: paper.bench_executor_speed(6, 2000))
        if args.quick else paper.bench_executor_speed,          # PR 5 executor
        "streaming": paper.bench_streaming,                     # PR 7 driver
        "faults": (lambda: paper.bench_faults(
            n_requests=800, study_requests=600)) if args.quick
        else paper.bench_faults,                                # PR 8 faults
        "service": (lambda: paper.bench_service(rounds=40, pairs=3))
        if args.quick else paper.bench_service,                 # ISSUE 9 service
        "policy_axis": (lambda: paper.bench_policy_axis(
            n_requests=400, n_baseline=4)) if args.quick
        else paper.bench_policy_axis,                           # ISSUE 10 axis
        "lm_traces": paper.bench_lm_traces,                     # framework tie-in
        "kernels": kernels_bench.bench_kernels,
        "roofline": lambda: roofline.csv_rows(roofline.load_records("sp")),
    }
    if args.section:
        if args.section not in sections:
            ap.error(f"unknown section {args.section!r}; "
                     f"choose from: {', '.join(sections)}")
        sections = {args.section: sections[args.section]}

    out_path = args.out
    if out_path is None and args.quick and not args.section:
        # full smoke runs refresh the tracked perf-trajectory artifact;
        # filtered runs only write JSON where --out points
        out_path = os.path.join(os.path.dirname(__file__) or ".",
                                "..", "artifacts", "BENCH_quick.json")

    print("name,value,derived")
    report: dict = {"quick": args.quick, "argv": sys.argv[1:],
                    "env": _env_header(), "sections": {}}
    failures = 0
    gate_values: dict = {}
    for name, fn in sections.items():
        rows, error = [], None
        t0 = time.perf_counter()
        try:
            for row in fn():
                rows.append(tuple(row))
                print(",".join(str(x) for x in row))
        except Exception as e:  # pragma: no cover
            failures += 1
            error = f"{type(e).__name__}:{e}"
            print(f"{name},ERROR,{error}")
        dt = time.perf_counter() - t0
        for r in rows:
            if r[0] in (STEADY_ROW, POLICY_ROW, EXEC_ROW,
                        PCACHE_HITS_ROW, PCACHE_MISSES_ROW,
                        STREAM_RATIO_ROW, STREAM_KEYS_ROW, STREAM_RSS_ROW,
                        FAULTS_KEYS_ROW, FAULTS_OFF_ROW, FAULTS_CKPT_ROW,
                        SERVICE_SCALING_ROW, SERVICE_COAL_ROW,
                        SERVICE_REJ_ROW,
                        PAXIS_COMPILES_ROW, PAXIS_BUCKETS_ROW,
                        PAXIS_SPEEDUP_ROW, PAXIS_BITIDENT_ROW,
                        PAXIS_PALLAS_ROW):
                gate_values[r[0]] = float(r[1])
        report["sections"][name] = {
            "rows": [list(r) for r in rows],
            "seconds": round(dt, 2),
            "error": error,
        }
        print(f"_section_{name}_seconds,{dt:.1f},wall", flush=True)
    steady_value = gate_values.get(STEADY_ROW)
    policy_value = gate_values.get(POLICY_ROW)

    # smoke gate: the steady-state engine speedup must be present and
    # at gate whenever the sim_speed section ran (bench_sim_speed also
    # asserts internally; this catches the row silently disappearing)
    if "sim_speed" in sections and not report["sections"]["sim_speed"]["error"]:
        if steady_value is None or steady_value < STEADY_GATE:
            failures += 1
            print(f"_steady_gate,FAIL,{STEADY_ROW}={steady_value}")
    # policy-VM gate: interpreting a scheduling program inside the scan
    # must stay within POLICY_GATE of the hard-coded scheduler
    if "policy_sweep" in sections \
            and not report["sections"]["policy_sweep"]["error"]:
        if policy_value is None or policy_value > POLICY_GATE:
            failures += 1
            print(f"_policy_gate,FAIL,{POLICY_ROW}={policy_value}")
    # executor gates: (a) the overlapped group executor must beat the
    # serial PR 4 loop warm (only meaningful with >1 hardware thread);
    # (b) the second persistent-cache process must skip every compile
    if "executor_speed" in sections \
            and not report["sections"]["executor_speed"]["error"]:
        from repro.core import executor
        exec_value = gate_values.get(EXEC_ROW)
        # overlap needs both hardware threads AND a multi-worker pool
        # (REPRO_EXEC_WORKERS=1 legitimately forces the serial loop)
        if (os.cpu_count() or 1) > 1 and executor.workers() > 1 \
                and (exec_value is None or exec_value < EXEC_GATE):
            failures += 1
            print(f"_executor_gate,FAIL,{EXEC_ROW}={exec_value}")
        hits = gate_values.get(PCACHE_HITS_ROW)
        misses = gate_values.get(PCACHE_MISSES_ROW)
        if not hits or misses is None or misses > 0:
            failures += 1
            print(f"_pcache_gate,FAIL,hits={hits},misses={misses}")
    # streaming gates: throughput parity with the single-shot steady
    # state, exactly one length-independent compile key, bounded RSS
    if "streaming" in sections \
            and not report["sections"]["streaming"]["error"]:
        ratio = gate_values.get(STREAM_RATIO_ROW)
        if ratio is None or ratio < STREAM_RATIO_GATE:
            failures += 1
            print(f"_streaming_gate,FAIL,{STREAM_RATIO_ROW}={ratio}")
        keys = gate_values.get(STREAM_KEYS_ROW)
        if keys is None or keys != 1:
            failures += 1
            print(f"_streaming_gate,FAIL,{STREAM_KEYS_ROW}={keys}")
        # ru_maxrss is process-wide high-water: sections that ran before
        # streaming (4 MiB rowclone traces, campaign sweeps) own the
        # peak in a full run, so the budget is only enforceable when
        # streaming runs alone (the BENCH_7.json protocol); the row
        # stays informational otherwise
        if args.section == "streaming":
            rss = gate_values.get(STREAM_RSS_ROW)
            if rss is None or rss > STREAM_RSS_BUDGET_MB:
                failures += 1
                print(f"_streaming_gate,FAIL,{STREAM_RSS_ROW}={rss}"
                      f">budget={STREAM_RSS_BUDGET_MB}")
        report["stream_rss_budget_mb"] = STREAM_RSS_BUDGET_MB
    # fault-subsystem gates: (a) faults=None must not perturb compile
    # keys; (b) the cheapest attached fault carry stays within 5% of no
    # fault model at all (the off path itself is byte-identical by key
    # discipline — bench_faults asserts the staged-HLO check); (c) a
    # checkpointed campaign re-run recomputes zero finished groups
    if "faults" in sections and not report["sections"]["faults"]["error"]:
        keys_eq = gate_values.get(FAULTS_KEYS_ROW)
        if keys_eq != 1:
            failures += 1
            print(f"_faults_gate,FAIL,{FAULTS_KEYS_ROW}={keys_eq}")
        off = gate_values.get(FAULTS_OFF_ROW)
        if off is None or off > FAULTS_OFF_GATE:
            failures += 1
            print(f"_faults_gate,FAIL,{FAULTS_OFF_ROW}={off}"
                  f">gate={FAULTS_OFF_GATE}")
        recomputed = gate_values.get(FAULTS_CKPT_ROW)
        if recomputed is None or recomputed != 0:
            failures += 1
            print(f"_faults_gate,FAIL,{FAULTS_CKPT_ROW}={recomputed}")
    # sweep-service gates: K tenants sharing one warm engine must keep
    # >= 0.7*K of a solo tenant's throughput (only reachable through
    # cross-client coalescing on a single device), dispatches must
    # actually mix clients, and the closed-loop load must ride the
    # default admission bounds without one typed rejection
    if "service" in sections and not report["sections"]["service"]["error"]:
        scaling = gate_values.get(SERVICE_SCALING_ROW)
        if scaling is None or scaling < SERVICE_SCALING_GATE:
            failures += 1
            print(f"_service_gate,FAIL,{SERVICE_SCALING_ROW}={scaling}"
                  f"<gate={SERVICE_SCALING_GATE}")
        coal = gate_values.get(SERVICE_COAL_ROW)
        if coal is None or coal <= 1.0:
            failures += 1
            print(f"_service_gate,FAIL,{SERVICE_COAL_ROW}={coal}<=1.0")
        rej = gate_values.get(SERVICE_REJ_ROW)
        if rej is None or rej != 0:
            failures += 1
            print(f"_service_gate,FAIL,{SERVICE_REJ_ROW}={rej}")

    # policy-axis gates (ISSUE 10): a 256-candidate sweep must compile
    # once per table-length BUCKET (not per program), beat the staged
    # per-program loop >= 5x per policy, and stay bit-identical to the
    # staged path — with the Pallas policy-VM kernel matching its
    # reference on the same tables
    if "policy_axis" in sections \
            and not report["sections"]["policy_axis"]["error"]:
        compiles = gate_values.get(PAXIS_COMPILES_ROW)
        buckets = gate_values.get(PAXIS_BUCKETS_ROW)
        if compiles is None or buckets is None or compiles != buckets:
            failures += 1
            print(f"_policy_axis_gate,FAIL,{PAXIS_COMPILES_ROW}={compiles}"
                  f"!=buckets={buckets}")
        speedup = gate_values.get(PAXIS_SPEEDUP_ROW)
        if speedup is None or speedup < PAXIS_SPEEDUP_GATE:
            failures += 1
            print(f"_policy_axis_gate,FAIL,{PAXIS_SPEEDUP_ROW}={speedup}"
                  f"<gate={PAXIS_SPEEDUP_GATE}")
        for rowname in (PAXIS_BITIDENT_ROW, PAXIS_PALLAS_ROW):
            if gate_values.get(rowname) != 1:
                failures += 1
                print(f"_policy_axis_gate,FAIL,{rowname}="
                      f"{gate_values.get(rowname)}")

    report["cache_stats"] = emulator.cache_stats()
    report["failures"] = failures
    print(f"_failures,{failures},smoke_gate")

    if out_path:
        out_path = os.path.abspath(out_path)
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"_report,{out_path},json")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
