"""Kernel micro-benchmarks: wall time of the XLA reference path on CPU
(the Pallas kernels themselves run in interpret mode here, so wall time
is meaningless for them — their perf story lives in the roofline, and
their correctness in tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels():
    rows = []
    from repro.models.attention import _sdpa_chunked
    B, S, H, hd = 1, 2048, 8, 64
    q = jnp.ones((B, S, H, hd), jnp.bfloat16)
    k = jnp.ones((B, S, 2, hd), jnp.bfloat16)
    v = jnp.ones((B, S, 2, hd), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: _sdpa_chunked(q, k, v, True, hd ** -0.5))
    rows.append(("xla_chunked_attn_2k_us", round(_time(f, q, k, v), 1),
                 "flash-kernel twin"))

    from repro.core.bloom import BloomFilter, bloom_probe_jnp
    import numpy as np
    bf = BloomFilter.build(np.arange(1000, dtype=np.uint32), m_bits=1 << 20, k=4)
    keys = jnp.arange(1 << 16, dtype=jnp.uint32)
    words = jnp.asarray(bf.bits)
    g = jax.jit(lambda w, kk: bloom_probe_jnp(w, 1 << 20, 4, kk))
    rows.append(("bloom_probe_64k_keys_us", round(_time(g, words, keys), 1),
                 "jnp path"))

    x = jnp.ones((1024, 4096), jnp.bfloat16)
    h = jax.jit(lambda x: x + 0)  # copy through XLA
    rows.append(("bulk_copy_8MB_us", round(_time(h, x), 1), "HBM-bound op"))

    # policy-VM batch scoring: 256 packed tables x one [N_LOADS, 64]
    # queue env — the policy-axis screening hot spot. The jnp reference
    # is the timed path on CPU; the Pallas kernel is checked for
    # bit-identity in interpret mode (its perf story is TPU Mosaic).
    from repro.core import smcprog
    from repro.kernels.ref import policy_vm_ref
    from repro.kernels.policy_vm import policy_vm_scores
    rng = np.random.RandomState(0)
    from repro.core.policysearch import random_program
    progs = [random_program(rng, name=f"p{i}") for i in range(256)]
    tables = jnp.asarray(smcprog.pack_stack(progs, bucket=8))
    envm = jnp.asarray(rng.randint(0, 1 << 16,
                                   (smcprog.N_LOADS, 64)), jnp.int32)
    pv = jax.jit(policy_vm_ref)
    rows.append(("policy_vm_256x64_ref_us", round(_time(pv, tables, envm), 1),
                 "jnp path (256 tables)"))
    ker = policy_vm_scores(tables, envm, interpret=True)
    ok = bool(jnp.array_equal(ker, pv(tables, envm)))
    rows.append(("policy_vm_kernel_bitident", ok, "pallas == ref"))
    return rows
