"""Child process for ``benchmarks --section executor_speed``'s
persistent-compile-cache claim: run a small heterogeneous sweep from a
COLD in-process state against the on-disk XLA cache dir given as argv[1],
then print one JSON line with the persistent-cache counters, results,
and wall time. The parent runs this twice: the first process populates
the cache (misses > 0), the second must load every executable from disk
(hits > 0, misses == 0) — i.e. a fresh process re-running a known sweep
skips the cold compiles entirely.

Kept as its own entry point (not ``python -c``) so the sweep stays in
one reviewable place and the cache keys cannot drift between the two
invocations.
"""
import json
import os
import sys
import time


def main() -> None:
    cache_dir = sys.argv[1]
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 400

    # order matters: both must precede the first jax computation
    from repro.utils.jax_compat import (enable_fast_cpu_scan,
                                        enable_persistent_compile_cache,
                                        persistent_cache_stats)
    enable_fast_cpu_scan()
    enable_persistent_compile_cache(cache_dir)

    import numpy as np

    from repro.core.emulator import Trace, run_many
    from repro.core.timescale import JETSON_NANO

    rng = np.random.RandomState(17)

    def mk(m):
        return Trace.of(kind=rng.randint(0, 2, m), bank=rng.randint(0, 16, m),
                        row=rng.randint(0, 4096, m),
                        delta=rng.randint(1, 8, m), dep=rng.randint(0, 2, m))

    # two length buckets x two modes -> four compile-key groups
    trs = [mk(n), mk(n + 8), mk(2 * n), mk(2 * n + 8)]
    modes = ["ts", "nots", "ts", "nots"]
    t0 = time.perf_counter()
    out = run_many(trs, JETSON_NANO, modes)
    wall = time.perf_counter() - t0
    print(json.dumps({
        "exec": [int(r["exec_cycles"]) for r in out],
        "pcache": persistent_cache_stats(),
        "wall_s": round(wall, 2),
    }))


if __name__ == "__main__":
    main()
