"""Paper-figure benchmarks (Table 1 / Figs. 8, 10-14 + Sec. 6 validation).

Each function returns a list of (name, value, derived) rows; ``run.py``
prints them as CSV. Modeled-CPU calibration: the TS configuration models
the Jetson Nano's A57 (3-wide OoO, 64B NEON copies -> few cycles/line);
the No-TS configuration models PiDRAM's 50 MHz single-issue rv64
(word-granular copy loop -> ~20 cycles/line). Same program, different
modeled CPUs — exactly the modeling gap the paper quantifies.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import emulator, traces
from repro.core.cachesim import LLC
from repro.core.campaign import Campaign
from repro.core.dram import Geometry
from repro.core.emulator import Trace, run, run_many
from repro.core.profiling import DeviceModel
from repro.core.techniques import RowClone, TRCDReduction
from repro.core.timescale import JETSON_NANO, PIDRAM_LIKE, SystemConfig

GEO = Geometry()
TS_LINE_DELTA = 4     # A57-class copy loop, cycles per 64B line
NOTS_LINE_DELTA = 20  # 50 MHz in-order rv64 copy loop

_DEVICE = None


def device():
    global _DEVICE
    if _DEVICE is None:
        _DEVICE = DeviceModel(GEO)
    return _DEVICE


# ---------------- Sec. 6: time-scaling validation ----------------

def bench_timescale_validation():
    """Sec. 6 validation, batched: every (kernel x {ts, reference}) arm
    runs in one Campaign (ts and reference share one executable), and
    the FPGA-clock invariance sweep is a second Campaign over the three
    SMC-speed SystemConfigs."""
    rows = []
    c = Campaign()
    for i, kern in enumerate(traces.POLYBENCH[:10]):
        tr, _ = traces.polybench_trace(kern, GEO, max_accesses=4000, seed=i)
        if tr is None:
            continue
        for mode in ("ts", "reference"):
            c.add(tr, JETSON_NANO, mode=mode, kern=kern.name)
    arms = {(r["kern"], r["mode"]): int(r["exec_cycles"]) for r in c.run()}
    kerns = sorted({k for k, _ in arms})
    errs = [abs(arms[(k, "ts")] - arms[(k, "reference")])
            / arms[(k, "reference")] for k in kerns]
    rows.append(("timescale_validation_avg_err", float(np.mean(errs)),
                 "paper<0.001"))
    rows.append(("timescale_validation_max_err", float(np.max(errs)),
                 "paper<0.01"))
    # invariance to FPGA-side clocks (the content of the claim)
    tr, _ = traces.polybench_trace(traces.POLYBENCH[0], GEO, 3000)
    inv = Campaign()
    for s in (50, 400, 5000):
        inv.add(tr, dataclasses.replace(JETSON_NANO,
                                        smc_cycles_per_decision=s),
                mode="ts", smc=s)
    execs = {int(r["exec_cycles"]) for r in inv.run()}
    rows.append(("timescale_fpga_invariance_spread", float(len(execs) - 1),
                 "0=exact"))
    return rows


# ---------------- Fig. 8: latency profile ----------------

def bench_latency_profile():
    """Average cycles/load vs working-set size; L1 modeled inside deltas,
    L2 = the LLC model, then DRAM. All (size x mode) points execute as
    one batched Campaign (one compile per system config)."""
    rows = []
    c = Campaign()
    cached = []
    for kb in (64, 256, 1024, 4096):
        out = traces.pointer_chase(kb * 1024, GEO, n_loads=3000)
        if out is None:
            cached.append(kb)
            continue
        tr, n_total, n_miss = out
        for mode, sysc in (("ts", JETSON_NANO), ("nots", PIDRAM_LIKE)):
            c.add(tr, sysc, mode=mode, kb=kb, n_total=n_total, n_miss=n_miss)
    recs = {(r["mode"], r["kb"]): r for r in c.run()}
    for kb in (64, 256, 1024, 4096):
        for mode in ("ts", "nots"):
            if kb in cached:
                rows.append((f"latency_{mode}_{kb}KiB_cyc_per_load", 2.0,
                             "cached"))
                continue
            r = recs[(mode, kb)]
            # cycles/load over ALL loads: hits cost ~2 cycles
            n_total, n_miss = r["n_total"], r["n_miss"]
            cyc = (2.0 * (n_total - n_miss)
                   + float(r["avg_load_latency_cycles"]) * n_miss) / n_total
            rows.append((f"latency_{mode}_{kb}KiB_cyc_per_load",
                         round(cyc, 2), f"miss_frac={n_miss/n_total:.2f}"))
    return rows


# ---------------- Figs. 10/11: RowClone ----------------

def bench_rowclone(setting="noflush"):
    rows = []
    rc_ts = RowClone(JETSON_NANO, device())
    rc_nots = RowClone(PIDRAM_LIKE, device())
    # clflush traces carry the per-line flush stream too; cap their size so
    # the section stays minutes, not tens of minutes, on one core
    sizes = (65536, 1 << 20, 4 << 20) if setting == "noflush"         else (65536, 512 << 10, 1 << 20)
    for wl in ("copy", "init"):
        # one batched campaign per (workload, system): the whole size
        # sweep shares a compile-key group instead of a jit per point
        a_all = rc_ts.evaluate_batch(sizes, wl, setting, "ts",
                                     cpu_line_delta=TS_LINE_DELTA)
        b_all = rc_nots.evaluate_batch(sizes, wl, setting, "nots",
                                       cpu_line_delta=NOTS_LINE_DELTA)
        sp_ts, sp_nots = [], []
        for nb, a, b in zip(sizes, a_all, b_all):
            sp_ts.append(a["rowclone"].speedup_vs_cpu)
            sp_nots.append(b["rowclone"].speedup_vs_cpu)
            rows.append((f"rowclone_{wl}_{setting}_{nb}B_ts",
                         round(sp_ts[-1], 2), "speedup_x"))
            rows.append((f"rowclone_{wl}_{setting}_{nb}B_nots",
                         round(sp_nots[-1], 2), "speedup_x"))
        rows.append((f"rowclone_{wl}_{setting}_avg_ts",
                     round(float(np.mean(sp_ts)), 2),
                     "paper_ts=15.0x_copy/1.8x_init"))
        rows.append((f"rowclone_{wl}_{setting}_avg_nots",
                     round(float(np.mean(sp_nots)), 2),
                     "paper_nots=306.7x_copy/36.7x_init"))
        rows.append((f"rowclone_{wl}_{setting}_inflation",
                     round(float(np.mean(sp_nots) / np.mean(sp_ts)), 2),
                     "paper~20x"))
    return rows


# ---------------- Figs. 12/13: tRCD reduction ----------------

def bench_trcd_profile():
    d = device()
    hm = d.trcd_heatmap(banks=2, rows=4096)
    return [
        ("trcd_strong_fraction", round(1 - d.weak_fraction(), 4), "paper=0.845"),
        ("trcd_min_ns", round(float(hm.min()), 2), "all<13.5"),
        ("trcd_max_ns", round(float(hm.max()), 2), "all<13.5"),
        ("trcd_row_autocorr", round(float(np.corrcoef(
            d.weak[0][:-1], d.weak[0][1:])[0, 1]), 3), "clustered>0.2"),
    ]


def bench_trcd_endtoend(n_kernels=None):
    d = device()
    t = TRCDReduction(JETSON_NANO, d)
    t.characterize()
    safety = t.safety_check()
    rows = [("trcd_bloom_false_neg", safety["false_negatives"], "must=0"),
            ("trcd_bloom_fpr", round(safety["false_positive_rate"], 4), "<0.05")]
    kerns = traces.POLYBENCH[:n_kernels] if n_kernels else traces.POLYBENCH
    names, trs = [], []
    for i, kern in enumerate(kerns):
        tr, n_acc = traces.polybench_trace(kern, GEO, max_accesses=6000, seed=i)
        if tr is None:
            continue
        names.append(kern.name)
        trs.append(tr)
    # whole suite, base + reduced arms, in one batched campaign
    speedups = []
    for name, r in zip(names, t.evaluate_traces(trs)):
        speedups.append(r["speedup"])
        rows.append((f"trcd_speedup_{name}", round(r["speedup"], 4), "x"))
    rows.append(("trcd_speedup_avg", round(float(np.mean(speedups)), 4),
                 "paper=1.0275"))
    rows.append(("trcd_speedup_max", round(float(np.max(speedups)), 4),
                 "paper=1.0976"))
    return rows


# ---------------- Fig. 14: simulation speed ----------------

def _timed_median(fn, reps=5):
    """Median warm wall-clock of fn() over reps (first call not timed)."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], out


def bench_sim_speed(steady_n=4000, steady_batch=8):
    rows = []
    names, trs = [], []
    for i, kern in enumerate(traces.POLYBENCH[:6]):
        tr, _ = traces.polybench_trace(kern, GEO, max_accesses=4000, seed=i)
        if tr is None:
            continue
        names.append(kern.name)
        trs.append(tr)
    # per-kernel emulation speed (warm cache, single dispatch each)
    speeds = []
    run_many(trs, JETSON_NANO, "ts")  # warm the batched jit cache
    for name, tr in zip(names, trs):
        run(tr, JETSON_NANO, "ts")  # warm the batch-of-one shape
        t0 = time.perf_counter()
        r = run(tr, JETSON_NANO, "ts")
        dt = time.perf_counter() - t0
        mhz = float(r["exec_cycles"]) / dt / 1e6
        speeds.append(mhz)
        rows.append((f"sim_speed_{name}_MHz", round(mhz, 2),
                     "emulated_cycles_per_host_sec"))
    rows.append(("sim_speed_avg_MHz", round(float(np.mean(speeds)), 2),
                 "paper~10MHz_on_FPGA"))
    # batched campaign speed: all kernels in one vmapped dispatch
    t0 = time.perf_counter()
    rs = run_many(trs, JETSON_NANO, "ts")
    dt = time.perf_counter() - t0
    total = float(sum(int(r["exec_cycles"]) for r in rs))
    rows.append(("sim_speed_batched_MHz", round(total / dt / 1e6, 2),
                 f"{len(trs)}_kernels_one_dispatch"))

    # steady-state engine A/B at N=steady_n: the O(Q)-per-slot core vs the
    # kept pre-optimization reference core (emulator.run_ref_many), same
    # batch, both warm — compile amortization plays no part here. The
    # paper's headline axis (Fig. 14) is evaluation throughput, so run.py
    # fails the run when this ratio is missing or below its 2x gate.
    rng = np.random.RandomState(11)
    steady = []
    for _ in range(steady_batch):
        steady.append(Trace.of(kind=rng.randint(0, 2, steady_n),
                               bank=rng.randint(0, 16, steady_n),
                               row=rng.randint(0, 4096, steady_n),
                               delta=rng.randint(1, 8, steady_n),
                               dep=rng.randint(0, 2, steady_n)))
    t_fast, out_fast = _timed_median(
        lambda: run_many(steady, JETSON_NANO, "ts"))
    t_ref, out_ref = _timed_median(
        lambda: emulator.run_ref_many(steady, JETSON_NANO, "ts"))
    fast_cycles = [int(r["exec_cycles"]) for r in out_fast]
    assert fast_cycles == [int(r["exec_cycles"]) for r in out_ref], \
        "optimized core diverged from the reference core"
    total = float(sum(fast_cycles))
    speedup = t_ref / max(t_fast, 1e-9)
    rows.append(("sim_speed_steady_MHz", round(total / t_fast / 1e6, 2),
                 f"{steady_batch}x{steady_n}_reqs_warm"))
    rows.append(("sim_speed_steady_ref_MHz", round(total / t_ref / 1e6, 2),
                 "pre_optimization_core"))
    # gate enforcement (>=2x) lives in benchmarks/run.py (STEADY_GATE),
    # which fails the run when this row is missing or below gate — an
    # exception here would discard the measurements needed to diagnose
    # the regression
    rows.append(("sim_speed_steady_speedup_x", round(speedup, 2),
                 "accept>=2x"))
    return rows


# ---------------- streaming driver: constant-memory unbounded traces ----------------

def bench_streaming(total_requests=1_000_000, n_streams=8, chunk=16384,
                    steady_n=4000, steady_batch=8):
    """The PR 7 streaming-driver benchmark, three claims per run.

    (1) Bit-identity sanity: a streamed trace equals the single-shot
    engine exactly (the full contract lives in tests/test_streaming.py
    and the hypothesis property; this is the smoke-level pin).

    (2) Constant-memory scale: ``total_requests`` requests — far beyond
    any padded single-shot bucket — flow through
    ``emulator.run_stream_many`` as ``n_streams`` synthetic streams
    (same request distribution as the sim_speed steady workload),
    generated window-by-window so the full trace never exists on host
    or device. Gated by ``run.py``: exactly ONE streaming compile key
    (``streaming_compile_keys``; a length-dependent key would recompile
    per bucket and its padded scan would not fit memory at this size),
    peak RSS under the recorded budget (``streaming_rss_mb``), and
    per-chunk throughput within 10% of the 8x{steady_n} single-shot
    steady state (``streaming_tput_ratio`` >= 0.9 — the freeze-gated
    window scan does the same O(Q)+O(1) slot work, the halo re-scan and
    host-side chunking are amortized by the chunk size, and the
    executor's prefetch thread hides window assembly under the scan).

    (3) The per-request cost decomposition behind (2): requests/sec for
    the stream vs the single-shot steady dispatch, plus wall and window
    counts so regressions localize.

    Both arms are timed end-to-end INCLUDING workload synthesis from
    the same ``traces.synthetic_stream`` generator — the single-shot
    arm rebuilds its 8x{steady_n} traces inside the timed region — so
    the ratio isolates the driver (windowed scan + halo + freeze +
    chunk assembly vs one padded dispatch) rather than charging
    generation of 1M requests to one arm only."""
    import resource

    rows = []
    # (1) smoke bit-identity, sized to straddle several chunk boundaries
    rng = np.random.RandomState(31)
    n = 2000
    tr = Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                  row=rng.randint(0, 4096, n), delta=rng.randint(1, 8, n),
                  dep=rng.randint(0, 2, n))
    a = run(tr, JETSON_NANO, "ts")
    s = emulator.run_stream(tr, JETSON_NANO, "ts", chunk=512)
    assert int(a["exec_cycles"]) == int(s["exec_cycles"]), \
        "streamed result diverged from single-shot"
    np.testing.assert_array_equal(a["t_resp"][:n], s["t_resp"])
    np.testing.assert_array_equal(a["t_issue"][:n], s["t_issue"])
    rows.append(("streaming_bit_identity", 1, "stream==single_shot"))

    # (2) single-shot steady-state baseline: same distribution AND same
    # generator as the streamed arm (bench_sim_speed's gate workload),
    # traces rebuilt inside the timed region. Both arms are measured
    # with the paired/interleaved GC-parked protocol (_paired_ratio) —
    # machine drift hits both arms of a pair equally, which matters
    # because the streamed arm is ~30x longer per measurement.
    SINGLE_REPS = 4  # batch the short arm per timed region: one 8x4000
    # dispatch is ~30ms, too short to time against a ~1s stream without
    # scheduler-quantum jitter dominating the per-pair ratio

    def single_shot():
        for r in range(SINGLE_REPS):
            trs = [next(iter(traces.synthetic_stream(
                steady_n, window=steady_n, seed=500 + r * 100 + i)))
                for i in range(steady_batch)]
            run_many(trs, JETSON_NANO, "ts")

    per = total_requests // n_streams
    last: dict = {}

    def stream():
        last["res"] = emulator.run_stream_many(
            [lambda i=i: traces.synthetic_stream(per, window=chunk, seed=i)
             for i in range(n_streams)],
            JETSON_NANO, "ts", chunk=chunk, collect="aggregate")

    # compile-cache misses across the warm-up AND every timed repeat
    # must total exactly one streaming compile: the key depends on
    # (chunk, batch, sys, mode), never on how many requests flow
    # through. The single-shot arm's own batched executable is warmed
    # BEFORE the counting window so the delta isolates streaming keys.
    single_shot()
    st0 = emulator.cache_stats()
    pair_r, t_single, wall = _paired_ratio(single_shot, stream, pairs=7)
    st1 = emulator.cache_stats()
    served = sum(int(r["served"]) for r in last["res"])
    assert served == total_requests, \
        f"stream served {served} of {total_requests}"
    single_n = SINGLE_REPS * steady_batch * steady_n
    single_rps = single_n / t_single
    stream_rps = total_requests / wall
    # per-pair median of (stream rps / single-shot rps): t_single/t_stream
    # scaled by the request-count ratio of the two arms
    ratio = pair_r * total_requests / single_n
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    keys = st1["misses"] - st0["misses"]
    windows = -(-per // chunk)  # final window drains the tail in place
    rows += [
        ("streaming_total_requests", total_requests,
         f"{n_streams}_streams_x_{per}"),
        ("streaming_wall_s", round(wall, 3), f"{windows}_windows_per_stream"),
        ("streaming_rps", round(stream_rps, 1), f"chunk={chunk}"),
        ("streaming_single_shot_rps", round(single_rps, 1),
         f"{steady_batch}x{steady_n}_steady"),
        # gate enforcement (>=0.9x, ==1 key, RSS budget) lives in run.py
        ("streaming_tput_ratio", round(ratio, 3),
         "accept>=0.9_paired_median"),
        ("streaming_compile_keys", keys, "accept==1_length_independent"),
        ("streaming_rss_mb", round(rss_mb, 1), "accept<=budget"),
    ]
    return rows


# ---------------- campaign subsystem: batched-vs-looped sweep ----------------

def bench_campaign_speed(n_traces=16, n_requests=180):
    """Compile-amortization benchmark for the run_many/Campaign path.

    A (n_traces x {ts, nots}) sweep is executed from a cold compile
    cache two ways: looped single-point ``run`` calls where every point
    pays a fresh jit compile (what the pre-campaign paper sweeps paid —
    their points differ in bucket / SystemConfig / mode / bloom, so the
    old per-point jit rarely hit cache; simulated by clearing the cache
    around each point) vs one batched Campaign that compiles at most
    once per (bucket, slot-budget, mode, bloom-shape) group.
    Steady-state (warm cache) wall-clocks are reported too: with the
    O(Q)-per-slot core the vmapped batch amortizes per-slot dispatch
    overhead across the batch axis, so batched execution now beats
    warm looping as well (campaign_warm_speedup_x; the enforced >=2x
    engine gate at N=4000 lives in sim_speed). Acceptance: cold
    speedup >= 3x."""
    rng = np.random.RandomState(7)
    trs = []
    for i in range(n_traces):
        n = n_requests + rng.randint(0, 64)  # varied length, one bucket
        trs.append(Trace.of(kind=np.zeros(n), bank=rng.randint(0, 16, n),
                            row=rng.randint(0, 4096, n),
                            delta=np.full(n, 3), dep=np.ones(n)))
    grid = [(tr, m) for m in ("ts", "nots") for tr in trs]
    c = Campaign()
    for tr, m in grid:
        c.add(tr, JETSON_NANO, mode=m)

    t0 = time.perf_counter()
    looped = []
    for tr, m in grid:
        emulator.cache_clear()  # every heterogeneous point recompiled
        looped.append(int(run(tr, JETSON_NANO, m)["exec_cycles"]))
    t_loop_cold = time.perf_counter() - t0
    for tr, m in grid:  # untimed pass: genuinely warm the jit cache
        run(tr, JETSON_NANO, m)
    t0 = time.perf_counter()
    looped_warm = [int(run(tr, JETSON_NANO, m)["exec_cycles"])
                   for tr, m in grid]
    t_loop_warm = time.perf_counter() - t0

    emulator.cache_clear()
    t0 = time.perf_counter()
    recs = c.run()
    t_batch_cold = time.perf_counter() - t0
    stats = emulator.cache_stats()
    t0 = time.perf_counter()
    c.run()
    t_batch_warm = time.perf_counter() - t0

    batched = [int(r["exec_cycles"]) for r in recs]
    assert batched == looped == looped_warm, \
        "batched campaign diverged from looped runs"
    expected_groups = len({(emulator._bucket(tr.n), m) for tr, m in grid})
    assert stats["misses"] == expected_groups, \
        f"compiled {stats['misses']} times for {expected_groups} groups"
    speedup = t_loop_cold / max(t_batch_cold, 1e-9)
    warm_speedup = t_loop_warm / max(t_batch_warm, 1e-9)
    if len(grid) >= 32:  # full-size run: amortization must dominate
        assert speedup >= 3.0, \
            f"cold campaign speedup {speedup:.2f}x below the 3x gate"
    return [
        ("campaign_looped_cold_s", round(t_loop_cold, 2),
         f"{len(grid)}_points_fresh_compile_each"),
        ("campaign_batched_cold_s", round(t_batch_cold, 2),
         f"compiles={stats['misses']}"),
        ("campaign_speedup_x", round(speedup, 2), "accept>=3x"),
        ("campaign_looped_warm_s", round(t_loop_warm, 2), "jit_cache_hot"),
        ("campaign_batched_warm_s", round(t_batch_warm, 2), "jit_cache_hot"),
        ("campaign_warm_speedup_x", round(warm_speedup, 2),
         "steady_state_batched_vs_looped"),
        ("campaign_compile_groups", stats["misses"],
         "one_per_bucket_mode_bloom"),
    ]


# ---------------- executor subsystem: overlapped groups + persistent cache ----------------

def _paired_ratio(f_base, f_new, pairs=7):
    """Noise-robust warm A/B: alternate base/new measurements (slow
    machine drift hits both arms of a pair equally) with the cyclic GC
    parked during each timed region (a gen-2 collection pauses every
    thread, which halves the overlapped executor's parallelism in
    whichever arm it lands on — the standard ``timeit`` hygiene).
    Returns (median per-pair ratio, median base s, median new s)."""
    import gc

    def timed(f):
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            f()
            return time.perf_counter() - t0
        finally:
            gc.enable()

    f_base()
    f_new()
    tb, tn = [], []
    for _ in range(pairs):
        tb.append(timed(f_base))
        tn.append(timed(f_new))
    ratios = sorted(b / max(n, 1e-9) for b, n in zip(tb, tn))
    return (ratios[len(ratios) // 2],
            sorted(tb)[len(tb) // 2], sorted(tn)[len(tn) // 2])


def bench_executor_speed(n_per=8, n_requests=3000):
    """The PR 5 campaign-executor benchmark, two claims per run.

    (1) Overlapped dispatch: a heterogeneous grid (>= 12 compile-key
    groups: three length buckets/budgets x {ts, nots} x {hard-coded
    scheduler, policy-VM program}) executed warm via ``Campaign.run()``
    (groups overlap across the executor's worker pool in LPT order;
    host packing of group k+1 proceeds while group k runs inside XLA,
    independent groups run concurrently across cores) vs
    ``run(serial=True)`` (the PR 4 in-order group loop). Bit-identity
    is asserted first; the paired-ratio wall-clock speedup is gated
    >= 1.5x by ``run.py`` (``executor_speed_overlap_speedup_x``)
    whenever >1 hardware thread is available.

    (2) Persistent compile cache: two fresh subprocesses run the same
    small sweep against one on-disk XLA cache
    (``benchmarks/pcache_child.py``). The first, cold, populates it
    (misses > 0); the second must load every executable from disk
    instead of recompiling (``executor_speed_pcache_second_hits`` > 0,
    misses == 0 — gated by ``run.py``) and its wall-clock shows the
    saved compile time.
    """
    import json
    import os
    import shutil
    import subprocess
    import sys as _sys

    from repro.core import smcprog

    rng = np.random.RandomState(41)

    def mk(n):
        return Trace.of(kind=rng.randint(0, 2, n), bank=rng.randint(0, 16, n),
                        row=rng.randint(0, 4096, n),
                        delta=rng.randint(1, 8, n), dep=rng.randint(0, 2, n))

    sys_prog = dataclasses.replace(JETSON_NANO,
                                   policy=smcprog.frfcfs_program())
    lengths = (n_requests // 2, n_requests, 2 * n_requests)  # 3 buckets
    c = Campaign()
    g = 0
    for length in lengths:
        for sysc in (JETSON_NANO, sys_prog):
            for mode in ("ts", "nots"):
                for j in range(n_per):
                    c.add(mk(length + rng.randint(0, 16)), sysc, mode=mode,
                          g=g, j=j)
                g += 1
    assert c.n_groups() >= 12, f"grid collapsed to {c.n_groups()} groups"

    serial = c.run(serial=True)   # warms every executable for both paths
    overlap = c.run()
    for a, b in zip(serial, overlap):
        assert int(a["exec_cycles"]) == int(b["exec_cycles"]), \
            "overlapped executor diverged from the serial group loop"
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
    speedup, t_serial, t_overlap = _paired_ratio(
        lambda: c.run(serial=True), lambda: c.run())
    rows = [
        ("executor_speed_groups", c.n_groups(), f"{len(c)}_points"),
        ("executor_speed_serial_warm_s", round(t_serial, 3),
         "pr4_in_order_group_loop"),
        ("executor_speed_overlap_warm_s", round(t_overlap, 3),
         "overlapped_executor"),
        # gate enforcement (>=1.5x, multicore hosts) lives in run.py
        ("executor_speed_overlap_speedup_x", round(speedup, 2),
         "accept>=1.5x_paired_median"),
    ]

    # (2) cross-process persistent compile cache, fresh dir under the
    # default artifacts/xla_cache location
    here = os.path.dirname(os.path.abspath(__file__))
    cache_dir = os.path.join(here, "..", "artifacts", "xla_cache",
                             f"_bench_probe_{os.getpid()}")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(here, "..", "src")) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    child = os.path.join(here, "pcache_child.py")
    try:
        outs = []
        for _ in range(2):
            p = subprocess.run([_sys.executable, child, cache_dir], env=env,
                               capture_output=True, text=True, timeout=600)
            assert p.returncode == 0, \
                f"pcache child failed: {p.stderr[-1500:]}"
            outs.append(json.loads(p.stdout.strip().splitlines()[-1]))
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    first, second = outs
    assert first["exec"] == second["exec"], \
        "persistent-cache processes disagreed on results"
    rows += [
        ("executor_speed_pcache_first_misses", first["pcache"]["misses"],
         f"cold_wall_s={first['wall_s']}"),
        ("executor_speed_pcache_second_hits", second["pcache"]["hits"],
         f"warm_wall_s={second['wall_s']}"),
        # gate enforcement (hits>0, misses==0) lives in run.py
        ("executor_speed_pcache_second_misses", second["pcache"]["misses"],
         "accept==0"),
    ]
    return rows


# ---------------- policy subsystem: software-defined scheduler sweep ----------------

def bench_policy_sweep(n_traces=8, n_requests=1200):
    """The MC-policy VM benchmark, two claims per run.

    (1) Interpreter overhead: the built-in FR-FCFS *program* (policy VM
    inside the scan) vs the hard-coded ``sys.scheduler`` branch, same
    traces, both warm — the VM stages to near-identical XLA, so the
    steady-state ratio must stay <= 1.3x (``run.py`` fails the run on
    the ``policy_sweep_interp_overhead_x`` row, same mechanism as the
    sim_speed gate). Correctness is asserted bit-exactly first.

    (2) Policy grid through Campaign: every built-in program over a
    bursty multi-bank workload in ONE Campaign — one compiled
    executable and one batched dispatch per program group (asserted on
    the compile-cache counters), with ts-mode results invariant to each
    program's length-derived SMC cost."""
    rng = np.random.RandomState(23)
    trs = []
    for _ in range(n_traces):
        # bursty arrivals keep several requests visible per decision,
        # so scheduling policy actually has choices to make
        delta = np.where(np.arange(n_requests) % 8 == 0, 400, 0)
        row = np.where(rng.rand(n_requests) < 0.6, 7,
                       rng.randint(0, 4096, n_requests))
        trs.append(Trace.of(kind=rng.randint(0, 2, n_requests),
                            bank=rng.randint(0, 4, n_requests),
                            row=row, delta=delta))
    from repro.core import smcprog
    sys_hard = dataclasses.replace(JETSON_NANO, window=8)
    sys_prog = dataclasses.replace(sys_hard,
                                   policy=smcprog.frfcfs_program())

    out_hard = run_many(trs, sys_hard, "ts")  # warm both executables
    out_prog = run_many(trs, sys_prog, "ts")
    for a, b in zip(out_hard, out_prog):
        assert int(a["exec_cycles"]) == int(b["exec_cycles"]), \
            "policy VM frfcfs diverged from the hard-coded scheduler"
        np.testing.assert_array_equal(a["t_resp"], b["t_resp"])
    t_hard, _ = _timed_median(lambda: run_many(trs, sys_hard, "ts"))
    t_prog, _ = _timed_median(lambda: run_many(trs, sys_prog, "ts"))
    overhead = t_prog / max(t_hard, 1e-9)

    rows = [
        ("policy_sweep_hardcoded_s", round(t_hard, 3),
         f"{n_traces}x{n_requests}_reqs_warm"),
        ("policy_sweep_vm_frfcfs_s", round(t_prog, 3), "policy_vm_scan"),
        # gate enforcement (<=1.3x) lives in benchmarks/run.py
        ("policy_sweep_interp_overhead_x", round(overhead, 3),
         "accept<=1.3x"),
    ]

    # (2) the policy grid: all built-ins, one batched dispatch per group
    emulator.cache_clear()
    programs = list(smcprog.builtin_programs().values())
    c = Campaign()
    for i, tr in enumerate(trs[:2]):
        # policy_axis=False on purpose: this section pins the STAGED
        # per-program path (the PR-4 contract the policy_axis section
        # measures its speedup against)
        c.add_policy_grid(tr, sys_hard, programs, mode="ts", i=i,
                          policy_axis=False)
    recs = c.run()
    stats = emulator.cache_stats()
    assert c.n_groups() == len(programs), \
        f"{c.n_groups()} groups for {len(programs)} programs"
    assert stats["misses"] == len(programs), \
        f"compiled {stats['misses']} times for {len(programs)} program groups"
    by = {(r["i"], r["policy"]): r for r in recs}
    base = {i: int(by[(i, "frfcfs")]["exec_cycles"]) for i in range(2)}
    for p in programs:
        execs = [int(by[(i, p.name)]["exec_cycles"]) for i in range(2)]
        rel = float(np.mean([base[i] / max(e, 1)
                             for i, e in enumerate(execs)]))
        rows.append((f"policy_sweep_{p.name}_vs_frfcfs", round(rel, 4),
                     f"smc_cycles={p.smc_cycles()}"))
    rows.append(("policy_sweep_grid_compiles", stats["misses"],
                 f"one_per_program_group_of_{len(programs)}"))
    return rows


def bench_policy_axis(n_requests=1200, n_policies=256, n_baseline=6):
    """ISSUE 10: the runtime policy operand + vmapped policy axis.

    (1) Compile scaling: a ``n_policies``-candidate sweep (two table-
    length buckets by construction) must compile exactly once per
    BUCKET, not once per program (``policy_axis_compiles`` ==
    ``policy_axis_buckets``, gated in run.py).

    (2) Throughput: the batched axis at ``n_policies`` candidates must
    beat the PR-4 staged per-program loop >= 5x per policy
    (``policy_axis_speedup_x``). The staged arm recompiles per program
    (content rides its compile key), so it is measured cold on
    ``n_baseline`` programs and extrapolated linearly — charitable to
    the baseline, since its per-policy cost only grows with the sweep.

    (3) Bit-identity: axis results must equal the staged runs exactly
    (``policy_axis_bitident``), and the Pallas policy-VM kernel must
    match the jnp reference on the same tables
    (``policy_axis_pallas_bitident``)."""
    from repro.core import smcprog
    from repro.core.policysearch import random_program

    rng = np.random.RandomState(29)
    delta = np.where(np.arange(n_requests) % 8 == 0, 400, 0)
    row = np.where(rng.rand(n_requests) < 0.6, 7,
                   rng.randint(0, 4096, n_requests))
    tr = Trace.of(kind=rng.randint(0, 2, n_requests),
                  bank=rng.randint(0, 4, n_requests),
                  row=row, delta=delta)
    sys = dataclasses.replace(JETSON_NANO, window=8)

    # candidate population: bucket-8 randoms + frfcfs, plus a handful of
    # wide (bucket-16) programs so the compile gate counts BUCKETS
    progs = [random_program(rng, name=f"cand{i}")
             for i in range(n_policies - 5)]
    progs.append(smcprog.frfcfs_program())
    while len(progs) < n_policies:
        p = random_program(rng, max_ops=14, name=f"wide{len(progs)}")
        if p.n_ops > 8:
            progs.append(p)
    buckets = sorted({smcprog.table_bucket(p.n_ops) for p in progs})

    # staged per-program baseline, cold: each program's content rides
    # its compile key, so every one pays a fresh XLA compile
    emulator.cache_clear()
    t0 = time.perf_counter()
    staged = [run(tr, dataclasses.replace(sys, policy=p), "ts")
              for p in progs[:n_baseline]]
    t_staged = time.perf_counter() - t0
    assert emulator.cache_stats()["misses"] == n_baseline, \
        "staged arm did not recompile per program"
    per_staged = t_staged / n_baseline

    # the policy axis, cold: one compile per table-length bucket
    emulator.cache_clear()
    t0 = time.perf_counter()
    recs = emulator.run_policies(tr, sys, progs, mode="ts",
                                 derive_cost=False)
    t_axis = time.perf_counter() - t0
    compiles = emulator.cache_stats()["misses"]
    per_axis = t_axis / len(progs)
    speedup = per_staged / max(per_axis, 1e-9)

    # bit-identity against the staged runs (axis pads t_resp to the
    # trace's length bucket exactly like the single-shot path)
    bitident = 1
    for p, a, b in zip(progs[:n_baseline], staged, recs):
        if int(a["exec_cycles"]) != int(b["exec_cycles"]) or \
                not np.array_equal(np.asarray(a["t_resp"]),
                                   np.asarray(b["t_resp"])):
            bitident = 0
            break

    # Pallas policy-VM kernel vs the jnp reference on one bucket
    import jax.numpy as jnp
    from repro.kernels.policy_vm import policy_vm_scores
    from repro.kernels.ref import policy_vm_ref
    b8 = [p for p in progs if smcprog.table_bucket(p.n_ops) == 8]
    tables = jnp.asarray(smcprog.pack_stack(b8, bucket=8))
    envm = jnp.asarray(rng.randint(0, 1 << 16, (smcprog.N_LOADS, 64)),
                       np.int32)
    pallas_ok = int(bool(jnp.array_equal(
        policy_vm_scores(tables, envm, interpret=True),
        policy_vm_ref(tables, envm))))

    return [
        ("policy_axis_n_policies", len(progs), f"{n_requests}_reqs"),
        ("policy_axis_buckets", len(buckets),
         "x".join(str(b) for b in buckets)),
        # gate enforcement (== buckets) lives in benchmarks/run.py
        ("policy_axis_compiles", compiles, "accept==buckets"),
        ("policy_axis_staged_per_policy_s", round(per_staged, 3),
         f"cold_{n_baseline}_programs"),
        ("policy_axis_batched_s", round(t_axis, 3),
         f"{len(progs)}_policies_cold"),
        ("policy_axis_batched_per_policy_s", round(per_axis, 5),
         "includes_bucket_compiles"),
        # gate enforcement (>= 5x) lives in benchmarks/run.py
        ("policy_axis_speedup_x", round(speedup, 2), "accept>=5x"),
        ("policy_axis_bitident", bitident,
         f"axis_vs_staged_{n_baseline}_programs"),
        ("policy_axis_pallas_bitident", pallas_ok, "pallas == ref"),
    ]


# ---------------- PR 8: fault injection + resumable campaigns ----------------

def bench_faults(n_requests=2000, n_traces=4, intensities=(0.5, 0.9),
                 study_requests=1500):
    """Fault-injection subsystem benchmark, three claims.

    (1) Zero-cost-off: ``faults=None`` must leave compile/group keys
    exactly as a config that never saw the fault subsystem, and the
    staged scan must be strictly SLIMMER than a fault-on lowering
    (asserted — if the off path ever stages fault ops, the texts
    converge). The gated ``faults_off_overhead_x`` row then bounds the
    runtime cost of the cheapest possible fault carry (a FaultModel
    with both error processes disabled — state threading only) at
    <= 1.05x the faults-off arm: the upper envelope of what
    attaching-but-disabling fault modeling can cost.

    (2) Checkpoint/resume: a checkpointed campaign re-run must load
    every finished group and recompute ZERO
    (``faults_ckpt_resume_recomputed``, gated == 0 in run.py), with
    bit-identical records.

    (3) The RowHammer mitigation study end-to-end: BER vs emulated
    slowdown for {unmitigated, PARA, TRR} x hammer intensities —
    the reliability/performance tradeoff rows the technique exists to
    produce."""
    import json as _json
    import os as _os
    import shutil as _shutil

    import jax.numpy as jnp

    from repro.core.faults import FaultModel
    from repro.core.techniques import RowHammerMitigationStudy

    rows = []
    rng = np.random.RandomState(41)
    trs = [Trace.of(kind=rng.randint(0, 2, n_requests),
                    bank=rng.randint(0, 16, n_requests),
                    row=rng.randint(0, 4096, n_requests),
                    delta=rng.randint(1, 8, n_requests))
           for _ in range(n_traces)]
    fm_on = FaultModel(seed=7, hammer_threshold=32, hammer_flip_fp=52000,
                       weak_fp=1200, retention_ticks=200)
    fm_disabled = FaultModel()           # carry threaded, zero error ops

    # (1a) key discipline: None is identical to never-attached; a real
    # model forks the group (campaigns never mix fault arms)
    n = trs[0].n
    keys_ok = (
        emulator.group_key(n, JETSON_NANO, "ts", None)
        == emulator.group_key(n, JETSON_NANO.with_faults(None), "ts", None)
        and emulator.group_key(n, JETSON_NANO, "ts", None)
        != emulator.group_key(n, JETSON_NANO.with_faults(fm_on), "ts", None))
    assert keys_ok, "faults=None perturbed the compile-key discipline"
    rows.append(("faults_off_compile_keys_equal", int(keys_ok), "accept==1"))

    # (1b) staged-program check: the fault-on lowering must be strictly
    # larger — if these converge, the off path is staging fault ops
    bucket = emulator._bucket(n)
    slots = emulator.slot_budget(bucket, trs[0].n_real)

    def lowered_lines(sysc):
        key = emulator.compile_key(bucket, 1, sysc, "ts", None, slots)
        r = emulator._batched_fn(key)
        dummies = [a() if callable(a) else jnp.zeros(a[0], a[1])
                   for a in r.avals]
        return len(r.jitted.lower(*dummies).as_text().splitlines())

    off_lines = lowered_lines(JETSON_NANO)
    on_lines = lowered_lines(JETSON_NANO.with_faults(fm_on))
    assert on_lines > off_lines, \
        f"fault-off scan ({off_lines} HLO lines) not slimmer than " \
        f"fault-on ({on_lines})"
    rows.append(("faults_off_hlo_lines", off_lines, "staged_scan"))
    rows.append(("faults_on_hlo_lines", on_lines, "must_exceed_off"))

    # (1c) runtime envelope: disabled-model carry vs no model at all
    sys_dis = JETSON_NANO.with_faults(fm_disabled)
    run_many(trs, JETSON_NANO, "ts")      # warm both executables
    run_many(trs, sys_dis, "ts")
    t_off, _ = _timed_median(lambda: run_many(trs, JETSON_NANO, "ts"))
    t_dis, _ = _timed_median(lambda: run_many(trs, sys_dis, "ts"))
    rows += [
        ("faults_none_s", round(t_off, 3), f"{n_traces}x{n_requests}_warm"),
        ("faults_disabled_model_s", round(t_dis, 3), "carry_only"),
        # gate enforcement (<= 1.05x) lives in benchmarks/run.py
        ("faults_off_overhead_x", round(t_dis / max(t_off, 1e-9), 3),
         "accept<=1.05x"),
    ]

    # (2) checkpoint/resume: finished groups load, nothing recomputes
    here = _os.path.dirname(_os.path.abspath(__file__))
    ck = _os.path.join(here, "..", "artifacts", "campaigns",
                       f"_bench_probe_{_os.getpid()}")
    try:
        def build():
            c = Campaign()
            for i, tr in enumerate(trs[:2]):
                c.add(tr, JETSON_NANO, mode="ts", i=i, arm="plain")
                c.add(tr, JETSON_NANO.with_faults(fm_on), mode="ts",
                      i=i, arm="faulty")
            return c

        first = build()
        r1 = first.run(checkpoint=ck)
        resumed = build()
        r2 = resumed.run(checkpoint=ck)
        assert resumed.last_run["computed"] == 0, resumed.last_run
        for a, b in zip(r1, r2):
            assert int(a["exec_cycles"]) == int(b["exec_cycles"])
            if "flips" in a:
                assert int(a["flips"]) == int(b["flips"])
        rows += [
            ("faults_ckpt_groups", first.last_run["groups"], "checkpointed"),
            ("faults_ckpt_resume_loaded", resumed.last_run["loaded"],
             "from_disk"),
            # gate enforcement (== 0) lives in benchmarks/run.py
            ("faults_ckpt_resume_recomputed", resumed.last_run["computed"],
             "accept==0"),
        ]
    finally:
        _shutil.rmtree(ck, ignore_errors=True)

    # (3) BER vs slowdown across mitigations x intensities
    study = RowHammerMitigationStudy(
        JETSON_NANO, fault_model=FaultModel(
            seed=7, hammer_threshold=48, hammer_flip_fp=52000))
    recs = study.evaluate(intensities=intensities,
                          n_requests=study_requests)
    for rec in recs:
        tag = f"i{int(round(rec['intensity'] * 100)):02d}"
        for name in study.programs:
            r = rec[name]
            rows.append((
                f"faults_study_{name}_{tag}_ber",
                round(r["bit_error_rate"], 6),
                _json.dumps({"flips": r["flips"],
                             "mitigations": r["mitigations"]},
                            separators=(",", ":"))))
            rows.append((
                f"faults_study_{name}_{tag}_slowdown_x",
                round(r["slowdown_vs_unmitigated"], 4),
                f"exec_cycles={r['exec_cycles']}"))
    hi = recs[-1]
    base_ber = hi[study.baseline]["bit_error_rate"]
    mitigated = [hi[nm]["bit_error_rate"] for nm in study.programs
                 if nm != study.baseline]
    assert base_ber > 0, "storm too weak: unmitigated arm never flipped"
    assert all(b < base_ber for b in mitigated), \
        f"mitigations did not reduce BER: base={base_ber}, {mitigated}"
    return rows


# ---------------- sweep service: multi-tenant shared engine ----------------

def bench_service(n_requests=8, round_pts=1, k_clients=4, rounds=60,
                  pairs=3):
    """Sweep-service multi-tenant throughput (ISSUE 9), three gated
    claims.

    (1) Shared-engine scaling (``service_scaling_x``, gated >= 0.7*K):
    K closed-loop clients hammering one ``SweepServer`` with same-group
    rounds of ``round_pts`` points each must reach at least 0.7*K the
    aggregate throughput of ONE client on its own server. On a
    single device this headroom can only come from cross-client
    coalescing: K concurrent rounds merge into one K*round_pts-point
    dispatch whose vmapped scan costs barely more than a round_pts one
    (batch amortization), so the shared server retires ~K rounds per
    dispatch wall. Each arm runs in its best configuration
    (``max_batch`` = its natural round size; both compile keys warmed
    before timing) — the comparison is K tenants SHARING a server vs a
    tenant OWNING one, not a rigged window.

    (2) Cross-client coalescing really happens
    (``service_clients_per_dispatch``, gated > 1.0): mean distinct
    clients per dispatch over the K-client phase.

    (3) No admission drops at default bounds (``service_rejected``,
    gated == 0): the closed-loop load must ride backpressure bounds
    without a single typed rejection.

    Arms alternate single/K ``pairs`` times (drift hits both), cyclic
    GC parked during timed regions as in ``_paired_ratio``; medians
    reported."""
    import gc
    import threading as _threading

    from repro.core.campaign import Point
    from repro.service import SweepClient, SweepServer

    rng = np.random.RandomState(0)

    def mk():
        return Trace.of(kind=rng.randint(0, 2, n_requests),
                        bank=rng.randint(0, 16, n_requests),
                        row=rng.randint(0, 4096, n_requests),
                        delta=rng.randint(1, 8, n_requests),
                        dep=rng.randint(0, 2, n_requests))

    pool = [[mk() for _ in range(round_pts)] for _ in range(k_clients)]

    def round_points(k):
        return [Point(t, JETSON_NANO, "ts") for t in pool[k]]

    def run_single():
        """One tenant owning a server: rounds flush at max_batch ==
        round_pts, no coalesce wait on its critical path."""
        with SweepServer(max_batch=round_pts,
                         coalesce_window_s=0.05) as srv:
            cli = SweepClient(server=srv, name="solo")
            cli.submit_points(round_points(0))
            cli.collect()                      # warm the round_pts key
            t0 = time.perf_counter()
            for _ in range(rounds):
                cli.submit_points(round_points(0))
                cli.collect()
            dt = time.perf_counter() - t0
        return rounds * round_pts / dt

    def run_k():
        """K tenants sharing one server: lockstep closed-loop rounds
        merge at max_batch == K*round_pts."""
        walls, errs = [], []
        barrier = _threading.Barrier(k_clients)
        with SweepServer(max_batch=k_clients * round_pts,
                         coalesce_window_s=0.005) as srv:
            def drive(k):
                try:
                    cli = SweepClient(server=srv, name=f"c{k}")
                    cli.submit_points(round_points(k))
                    cli.collect()              # warm the K*round_pts key
                    barrier.wait()
                    t0 = time.perf_counter()
                    for _ in range(rounds):
                        cli.submit_points(round_points(k))
                        cli.collect()
                    walls.append(time.perf_counter() - t0)
                except BaseException as e:  # pragma: no cover
                    errs.append(e)
            threads = [_threading.Thread(target=drive, args=(k,))
                       for k in range(k_clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(600)
            st = srv.stats()
        if errs:
            raise errs[0]
        return k_clients * rounds * round_pts / max(walls), st

    def timed(f):
        gc.collect()
        gc.disable()
        try:
            return f()
        finally:
            gc.enable()

    # warm BOTH compile keys (round_pts and K*round_pts batch buckets)
    # through the exact key-derivation path the service dispatches use,
    # so no arm ever pays a compile inside a timed region
    run_many([t for c in pool for t in c], JETSON_NANO, "ts")
    run_many(pool[0], JETSON_NANO, "ts")

    singles, ks, coals, rej = [], [], [], 0
    for _ in range(pairs):
        singles.append(timed(run_single))
        tput_k, st = timed(run_k)
        ks.append(tput_k)
        coals.append(st["coalesce_ratio"])
        rej += int(st["rejected"])
    tput_s = sorted(singles)[len(singles) // 2]
    tput_k = sorted(ks)[len(ks) // 2]
    coal = sorted(coals)[len(coals) // 2]
    scaling = tput_k / max(tput_s, 1e-9)
    return [
        ("service_tput_single_pps", round(tput_s, 1),
         f"1_client_rounds_of_{round_pts}x{n_requests}req"),
        ("service_tput_k_pps", round(tput_k, 1),
         f"{k_clients}_clients_shared_server"),
        ("service_scaling_x", round(scaling, 2),
         f"accept>={0.7 * k_clients:.1f}_via_coalesced_batching"),
        ("service_clients_per_dispatch", round(coal, 2),
         "accept>1_mean_distinct_clients_per_dispatch"),
        ("service_rejected", rej, "accept==0_at_default_bounds"),
    ]


# ---------------- LM x EasyDRAM: the framework tie-in ----------------

def bench_lm_traces():
    """DRAM-level evaluation of LM serving traffic + RowClone KV fork.
    All arches' decode traces and the kv-fork pair run through batched
    campaign calls; the TRCD base/reduced arms for the whole arch set
    share one Campaign inside ``evaluate_traces``."""
    from repro.configs import get_config
    rows = []
    d = device()
    archs = ("qwen2_1_5b", "rwkv6_3b")
    arch_trs = [traces.lm_decode_trace(get_config(a), seq_len=4096, geo=GEO,
                                       max_requests=6000) for a in archs]
    base = run_many(arch_trs, JETSON_NANO, "ts")
    t = TRCDReduction(JETSON_NANO, d)
    trcd = t.evaluate_traces(arch_trs)
    for arch, r, rr in zip(archs, base, trcd):
        rows.append((f"lm_decode_trace_{arch}_cycles", int(r["exec_cycles"]),
                     f"reqs={r['n_requests']}"))
        rows.append((f"lm_decode_trace_{arch}_trcd_speedup",
                     round(rr["speedup"], 4), "x"))
    # KV-page fork via RowClone vs CPU copy (serving-side case study)
    tr_rc, _ = traces.kv_fork_trace(16, 8192, GEO, "rowclone", d)
    tr_cpu, _ = traces.kv_fork_trace(16, 8192, GEO, "cpu", d)
    fork = run_many([tr_cpu, tr_rc], JETSON_NANO, "ts")
    a, b = (int(r["exec_cycles"]) for r in fork)
    rows.append(("kv_fork_rowclone_speedup", round(a / max(b, 1), 2), "x"))
    return rows
